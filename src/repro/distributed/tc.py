"""Distributed TCIM: shard the work list across the mesh, psum one scalar.

TCIM's reduction is a commutative monoid (integer +), so the parallelization
is embarrassing at slice-pair granularity: every device owns a contiguous
stripe of the work list, gathers its slice words, runs the AND+BitCount
kernel locally, and a single scalar ``psum`` closes the computation. This is
also why the engine is elastic- and straggler-friendly (runtime/elastic.py):
work stripes can be re-dealt to any surviving device set without touching
the slice data.

Slice data placement (chosen by ``core.plan.plan_execution``):
  * ``replicated``  (default) — row/col slice stores live on every device;
    right for graphs up to a few GB of SBF (all SNAP-class graphs: Table III
    tops out at 16.8 MB) and removes all communication except the final psum.
  * ``sharded_cols`` — the column store is genuinely ``NamedSharding``-
    sharded over the mesh (contiguous row ranges, dim 0 split across every
    axis); the row store stays replicated. The planner owner-groups the work
    list so each pair executes on the shard holding its column slice with
    *shard-local* indices — no per-step all-gather of column data, only each
    shard's own index stripe travels, and a single scalar psum still closes
    every step. ``ShardedColsExecutor`` is the device-resident unit: one
    Executor's worth of state (store shard + traced step + stripe schedule)
    per mesh device. For graphs whose SBF exceeds one device's HBM.
  * ``sharded_2d`` — BOTH stores sharded over a 2-axis mesh: device
    ``(i, j)`` holds row-store range ``i`` (sharded over the first mesh
    axis, replicated over the second) and column-store range ``j`` (the
    transpose). The planner routes every pair to its ``(row_shard,
    col_shard)`` owner block with block-local coordinates on both axes and
    balances the ranges by *pair count* (weighted split), so per-block work
    stays near-uniform even on degree-ordered graphs. The placement that
    lets row stores exceed one device's memory; ``Sharded2DExecutor`` is
    the device-resident unit, reusing the replicated Executor's pow2 step
    buckets and double-buffered index staging.

Both sharded executors run their owner stripes through
``core.plan.StripeSchedule`` (see ``_StripeScheduleDriver``): ``packed``
per-shard window cursors by default — drained shards stop consuming the
per-step pair budget, so imbalanced fixed-bounds replans take
``~ceil(total/budget)`` psum steps instead of lockstep's
``ceil(longest * num_shards / budget)`` — with the legacy ``lockstep``
policy kept as the benchmark/CI baseline. ``count*_async`` variants defer
the final host readback behind a ``CountFuture`` so fleet serving overlaps
graph i's close with graph i+1's stripe assembly.
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.executor import CountFuture, staged_uploads
from repro.core.plan import (
    SCHEDULES,
    DeviceTopology,
    ExecutionPlan,
    StripeSchedule,
    build_stripe_schedule,
    even_range_bounds,
    plan_execution,
    pow2_ceil as _pow2_ceil,
    shard_col_bounds,
)
from repro.core.sbf import SlicedBitmap, Worklist
from repro.kernels.ops import INT32_SAFE_WORDS
from repro.kernels.tc_gather_popcount import gather_total_reference
from repro.runtime.contracts import no_host_sync
from repro.runtime.fault import CountInterrupted

__all__ = [
    "shard_worklist",
    "distributed_tc_count",
    "distributed_tc_count_async",
    "make_tc_step",
    "ShardedColsExecutor",
    "Sharded2DExecutor",
    "pooled_sharded_executor",
    "pooled_sharded_2d_executor",
    "clear_sharded_executor_cache",
    "TC_PLACEMENTS",
]

TC_PLACEMENTS = ("replicated", "sharded_cols", "sharded_2d")


def shard_worklist(wl: Worklist, num_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad the pair index arrays to a multiple of num_shards and stack.

    Padding points at record 0 on both sides with a sentinel weight of zero —
    implemented by masking in the step function, so padded lanes are exact
    no-ops regardless of what record 0 holds.
    Returns (row_pos [S, ppd], col_pos [S, ppd]) int32 plus an implicit mask
    encoded as negative indices.
    """
    p = wl.num_pairs
    per = -(-max(p, 1) // num_shards)
    total = per * num_shards
    row = np.full(total, -1, dtype=np.int32)
    col = np.full(total, -1, dtype=np.int32)
    row[:p] = wl.pair_row_pos.astype(np.int32)
    col[:p] = wl.pair_col_pos.astype(np.int32)
    return row.reshape(num_shards, per), col.reshape(num_shards, per)


def _local_count(row_data, col_data, row_idx, col_idx):
    """Per-device partial count: the executor's fused mirror (portable jnp).

    Shares ``gather_total_reference`` with core.executor — identical
    negative-index no-op contract, so ``shard_worklist`` padding composes
    with the fused execute semantics for free.
    """
    return gather_total_reference(row_data, col_data, row_idx, col_idx)


def make_tc_step(mesh: Mesh, axis_names: tuple[str, ...]):
    """Build the pjit'd distributed TC step for a mesh.

    Data layout: slice stores replicated; work-list stripes sharded over all
    mesh axes (flattened). Returns a function
    ``step(row_data, col_data, row_idx, col_idx) -> total (replicated)``.
    """
    flat = P(axis_names)  # leading dim sharded over every axis

    def step(row_data, col_data, row_idx, col_idx):
        def local(row_data, col_data, r, c):
            # r, c: this device's stripe of the flat work list.
            partial = _local_count(row_data, col_data, r, c)
            return jax.lax.psum(partial[None], axis_names)

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), P(), flat, flat),
            out_specs=P(),
        )(row_data, col_data, row_idx, col_idx)[0]

    return jax.jit(
        step,
        in_shardings=(
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, flat),
            NamedSharding(mesh, flat),
        ),
        out_shardings=NamedSharding(mesh, P()),
    )


def make_sharded_cols_step(mesh: Mesh, axis_names: tuple[str, ...]):
    """The pjit'd step for ``sharded_cols`` placement.

    Data layout: row store replicated; column store's dim 0 sharded over
    every mesh axis (each device holds one contiguous block of column
    slices); index stripes sharded the same flat way, with *block-local*
    column positions. Inside shard_map every device runs the fused mirror
    against only its resident column block — no all-gather — and one scalar
    psum closes the step.
    """
    flat = P(axis_names)
    col_spec = P(axis_names, None)

    def step(row_data, col_block, row_idx, col_idx):
        def local(row_data, col_block, r, c):
            partial = gather_total_reference(row_data, col_block, r, c)
            return jax.lax.psum(partial[None], axis_names)

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), col_spec, flat, flat),
            out_specs=P(),
        )(row_data, col_block, row_idx, col_idx)[0]

    return jax.jit(
        step,
        in_shardings=(
            NamedSharding(mesh, P()),
            NamedSharding(mesh, col_spec),
            NamedSharding(mesh, flat),
            NamedSharding(mesh, flat),
        ),
        out_shardings=NamedSharding(mesh, P()),
    )


class _StripeScheduleDriver:
    """Shared sharded execute driver: schedule -> staged uploads -> close.

    Both sharded executors hold NamedSharding-resident ``row_store`` /
    ``col_store`` arrays, a traced ``_step``, and plan validation
    (``_check_plan``); this mixin owns everything placement-independent:

    * **Stripe scheduling.** ``count_plan*`` runs the plan's owner stripes
      through ``core.plan.build_stripe_schedule`` under the executor's
      ``schedule`` policy (``packed`` by default — per-shard cursors, so a
      drained shard stops consuming the step budget; ``lockstep`` keeps the
      legacy shared-window baseline). The step budget is the caller's
      memory bound AND the int32 psum bound: ``min(plan.chunk_pairs,
      INT32_SAFE_WORDS // words_per_slice)`` **real pairs per step** —
      NOT per shard, so a step never stages ``num_shards`` times the
      caller's bound the way the pre-schedule driver did.
    * **Async close.** ``count_plan_async`` returns a ``CountFuture`` with
      every psum step dispatched through double-buffered index staging;
      the final host readback happens at ``result()``, so fleet callers
      overlap graph i's close with graph i+1's stripe assembly.
    """

    def _validate_int32_floor(self, noun: str, remedy: str) -> None:
        """Constructor guard: the packed scheduler's width-1 progress floor
        can put one pair from EVERY shard in a step, so even that worst
        case must fit the closing psum's int32 accumulator."""
        safe = INT32_SAFE_WORDS // max(self.words_per_slice, 1)
        if safe // self.num_shards < 1:
            raise ValueError(
                f"words_per_slice={self.words_per_slice} x {self.num_shards} "
                f"{noun} cannot give every {noun.rstrip('s')} even one "
                f"int32-safe pair per step (INT32_SAFE_WORDS="
                f"{INT32_SAFE_WORDS}); use a smaller slice_bits or {remedy}"
            )

    def stripe_schedule(self, plan: ExecutionPlan) -> StripeSchedule:
        """The schedule ``count_plan`` would run for this plan (inspectable:
        benchmarks and the CI gate read ``num_steps`` off it).

        The budget honors BOTH memory bounds — the plan's and the
        executor's own ``chunk_pairs`` (a caller-built plan may carry a
        larger chunk than this executor was configured for) — plus the
        int32 psum bound.
        """
        safe = INT32_SAFE_WORDS // max(self.words_per_slice, 1)
        budget = min(max(plan.chunk_pairs, 1), max(self.chunk_pairs, 1), safe)
        return build_stripe_schedule(
            [s.num_pairs for s in plan.stripes], budget, policy=self.schedule
        )

    def _staged_windows(
        self, sched: StripeSchedule, plan: ExecutionPlan, start_step: int = 0
    ):
        """Double-buffered device index windows via the *compact* emission.

        ``StripeSchedule.emit_compact`` hands back per-shard rows, with
        every drained shard's all-sentinel row served from one shared
        cached buffer — so once a shard's stripe is exhausted its rows are
        never re-filled or re-copied host-side again (the budget-aware
        packed-width fix; ``staged_lanes`` vs ``total_lanes`` quantifies
        it, gated in CI). Each device then materializes its own row through
        ``jax.make_array_from_callback`` under the same flat sharding the
        dense ``device_put`` used — bit-identical step inputs.
        """
        flat = NamedSharding(self.mesh, P(self.axis_names))

        def put(step):
            bucket, row_rows, col_rows = step
            shape = (len(row_rows) * bucket,)

            def mk(rows):
                return jax.make_array_from_callback(
                    shape,
                    flat,
                    lambda idx: rows[(idx[0].start or 0) // bucket],
                )

            return mk(row_rows), mk(col_rows)

        return staged_uploads(
            sched.emit_compact(plan.stripes, start_step),
            put,
            double_buffer=self.double_buffer,
        )

    @no_host_sync()
    def count_plan_async(self, plan: ExecutionPlan) -> CountFuture:
        """Dispatch every scheduled psum step; defer the exact host sum.

        Contract (``TCIM_CONTRACTS=1``): the step loop stages windows and
        enqueues psum steps without ever reading a device scalar back — the
        one host sync is the ``CountFuture`` close (or, on the resumable
        path, its periodic cursor commits).
        """
        self._check_plan(plan)
        sched = self.stripe_schedule(plan)
        if sched.num_steps == 0:
            return CountFuture([])  # empty worklist: nothing dispatched
        staged = self._staged_windows(sched, plan)
        return CountFuture(
            [
                self._step(self.row_store, self.col_store, ridx, cidx)
                for ridx, cidx in staged
            ]
        )

    def count_plan(self, plan: ExecutionPlan) -> int:
        """Count an owner-grouped plan. One exact host sum at the end."""
        return self.count_plan_async(plan).result()

    def count_plan_resumable(
        self,
        plan: ExecutionPlan,
        *,
        checkpoint_every: int = 8,
        checkpointer=None,
        injector=None,
        monitor=None,
        monitor_interrupts: bool = False,
        start_step: int = 0,
        base_total: int = 0,
        attempt: int = 0,
    ) -> tuple[int, dict]:
        """The checkpointed step loop: every ``checkpoint_every`` psum steps
        the pending device scalars are read back, folded into the exact
        committed total, and the ``(shard_cursors, total)`` cursor is saved
        through ``checkpointer`` (async — file I/O overlaps the next steps).
        Any failure past that point surfaces as ``CountInterrupted``
        carrying the last committed cursor, so a resume replays at most
        ``checkpoint_every`` steps; replay is exact because uncommitted
        steps contributed nothing to the committed total (commutative
        integer monoid over disjoint pair windows).

        ``checkpointer`` is duck-typed (``distributed.resilient
        .TCCheckpoint``): ``save_snapshot`` persists the SBF stores +
        full worklist once per attempt, ``save_cursor`` the per-commit
        cursor. ``injector`` (``runtime.fault.FailureInjector``) hooks
        each dispatch; ``monitor`` (``StragglerMonitor``) makes the loop
        block per step to time it — observability costs the dispatch
        pipelining, so it is opt-in — and with ``monitor_interrupts`` a
        straggler flag commits and raises (reason ``"straggler"``) for
        the caller's checkpoint-and-remesh policy. ``start_step`` /
        ``base_total`` / ``attempt`` are the same-schedule resume inputs.

        Returns ``(total, info)``; ``info`` records steps, commits, and
        the step-time EWMA when monitored.
        """
        self._check_plan(plan)
        sched = self.stripe_schedule(plan)
        n = sched.num_steps
        if not 0 <= start_step <= n:
            raise ValueError(f"start_step must be in [0, {n}], got {start_step}")
        every = int(checkpoint_every) if checkpoint_every else 0
        if checkpointer is not None:
            checkpointer.save_snapshot(
                self._sbf, plan, attempt=attempt, base_total=base_total,
                schedule=self.schedule,
            )
        total = int(base_total)
        committed_step = start_step
        pending: list = []
        info: dict = {
            "steps": n,
            "start_step": start_step,
            "attempt": attempt,
            "checkpoints": 0,
        }

        def commit(upto: int) -> None:
            nonlocal total, committed_step
            if pending:
                # Small windows (the cadence path) read scalars one by one:
                # a jnp.stack over <= checkpoint_every scalars costs more in
                # dispatch than the transfers it batches. Big windows (no
                # cadence: one commit for the whole count) still stack.
                vals = (
                    # tclint: sync-ok(resumable cursor commit: the periodic exact fold)
                    np.asarray(jnp.stack(pending))
                    if len(pending) > 16
                    else pending
                )
                total += sum(int(v) for v in vals)
                pending.clear()
            committed_step = upto
            if checkpointer is not None:
                checkpointer.save_cursor(
                    attempt, upto, sched.cursor_after(upto), total, plan
                )
                info["checkpoints"] += 1

        staged = self._staged_windows(sched, plan, start_step)
        step_i = start_step
        try:
            for ridx, cidx in staged:
                if injector is not None:
                    injector.check(step_i)
                if monitor is not None:
                    monitor.start_step()
                t = self._step(self.row_store, self.col_store, ridx, cidx)
                pending.append(t)
                if monitor is not None:
                    jax.block_until_ready(t)
                    flagged = monitor.end_step()
                    ewma = getattr(monitor, "ewma", None)
                    if ewma is not None:
                        info["step_ewma_s"] = float(ewma)
                    if flagged:
                        info["straggler_flags"] = (
                            info.get("straggler_flags", 0) + 1
                        )
                    if flagged and monitor_interrupts:
                        # The flagged step finished — commit through it so
                        # the remesh replays nothing.
                        commit(step_i + 1)
                        raise CountInterrupted(
                            f"straggler flagged at step {step_i} of {n}",
                            failed_step=step_i + 1,
                            committed_step=committed_step,
                            committed_total=total,
                            shard_cursors=sched.cursor_after(committed_step),
                            reason="straggler",
                            attempt=attempt,
                        )
                step_i += 1
                if every and step_i < n and (step_i - start_step) % every == 0:
                    commit(step_i)
            commit(n)
        except CountInterrupted:
            raise
        except Exception as e:
            raise CountInterrupted(
                f"sharded count failed at step {step_i} of {n}: {e}",
                failed_step=step_i,
                committed_step=committed_step,
                committed_total=total,
                shard_cursors=sched.cursor_after(committed_step),
                reason="failure",
                attempt=attempt,
            ) from e
        return total, info

    def count_resumable(self, wl: Worklist, **kwargs) -> tuple[int, dict]:
        """``count_plan_resumable`` over a work list planned against this
        executor's resident store ranges."""
        return self.count_plan_resumable(self._plan(wl), **kwargs)

    def count_async(self, wl: Worklist) -> CountFuture:
        """``count`` with the final host readback deferred to ``result()``."""
        return self.count_plan_async(self._plan(wl))

    def count(self, wl: Worklist) -> int:
        """Count a work list against the executor's resident stores."""
        return self.count_async(wl).result()


class ShardedColsExecutor(_StripeScheduleDriver):
    """Device-resident ``sharded_cols`` execute stage for one mesh.

    One Executor's worth of state per column-store shard: the shard's block
    of column slices stays resident on its device (uploaded once, verifiably
    sharded — see ``col_store.sharding``), the row store is replicated, and
    the traced step is shared across counts. ``count`` schedules any work
    list through the planner's owner-grouped stripes under the ``schedule``
    policy (see ``_StripeScheduleDriver``); pow2 step buckets keep retraces
    bounded exactly like ``core.executor.Executor``.
    """

    def __init__(
        self,
        sbf: SlicedBitmap,
        mesh: Mesh,
        *,
        chunk_pairs: int = 1 << 20,
        double_buffer: bool = True,
        schedule: str = "packed",
    ):
        if schedule not in SCHEDULES:
            raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
        self.schedule = schedule
        self.mesh = mesh
        self.axis_names = tuple(mesh.axis_names)
        self.num_shards = int(np.prod(mesh.devices.shape))
        self.words_per_slice = int(sbf.words_per_slice)
        self.chunk_pairs = chunk_pairs
        self.double_buffer = double_buffer
        per, padded = shard_col_bounds(len(sbf.col_slice_idx), self.num_shards)
        self.col_shard_rows = per
        self.col_bounds = even_range_bounds(len(sbf.col_slice_idx), self.num_shards)
        # tclint: sync-ok(one-time shard repack at executor construction; ROADMAP: device-resident resharding)
        col = np.asarray(sbf.col_slice_data)
        if padded != col.shape[0]:
            col = np.concatenate(
                [col, np.zeros((padded - col.shape[0], col.shape[1]), col.dtype)]
            )
        # The actual sharded placement: dim 0 split over every mesh axis.
        self.col_store = jax.device_put(
            col, NamedSharding(mesh, P(self.axis_names, None))
        )
        self.row_store = jax.device_put(
            # tclint: sync-ok(one-time shard repack at executor construction; ROADMAP: device-resident resharding)
            np.asarray(sbf.row_slice_data), NamedSharding(mesh, P())
        )
        self._step = make_sharded_cols_step(mesh, self.axis_names)
        self._sbf = sbf
        self._validate_int32_floor("shards", "fewer shards")

    def _plan(self, wl: Worklist) -> ExecutionPlan:
        return plan_execution(
            self._sbf,
            wl,
            placement="sharded_cols",
            num_shards=self.num_shards,
            chunk_pairs=self.chunk_pairs,
        )

    def _check_plan(self, plan: ExecutionPlan) -> None:
        if plan.placement != "sharded_cols":
            raise ValueError(
                f"plan placement {plan.placement!r} is not 'sharded_cols'"
            )
        if plan.num_shards != self.num_shards:
            raise ValueError(
                f"plan has {plan.num_shards} shards, mesh has {self.num_shards}"
            )
        if plan.col_shard_rows != self.col_shard_rows or (
            plan.col_bounds is not None
            and not np.array_equal(plan.col_bounds, self.col_bounds)
        ):
            raise ValueError(
                "plan's shard-local coordinates assume different column "
                f"ranges (rows/shard {plan.col_shard_rows} vs "
                f"{self.col_shard_rows}); the plan was built for a different "
                "SBF, shard count, or split"
            )


def make_sharded_2d_step(mesh: Mesh, axis_names: tuple[str, ...]):
    """The pjit'd step for ``sharded_2d`` placement on a 2-axis mesh.

    Data layout: row store's dim 0 sharded over the FIRST mesh axis
    (replicated over the second), column store's dim 0 sharded over the
    SECOND axis (replicated over the first) — device ``(i, j)`` holds
    exactly row block ``i`` and col block ``j``. Index stripes are sharded
    over both axes flattened (stripe order is row-major ``i*C + j``, which
    is the mesh's device order), carrying *block-local* coordinates on both
    sides. Inside shard_map every device runs the fused mirror against only
    its resident blocks — owner-compute, no all-gather — and one scalar
    psum over both axes closes the step.
    """
    row_axis, col_axis = axis_names
    row_spec = P(row_axis, None)
    col_spec = P(col_axis, None)
    flat = P(axis_names)

    def step(row_block, col_block, row_idx, col_idx):
        def local(row_block, col_block, r, c):
            partial = gather_total_reference(row_block, col_block, r, c)
            return jax.lax.psum(partial[None], axis_names)

        return shard_map(
            local,
            mesh=mesh,
            in_specs=(row_spec, col_spec, flat, flat),
            out_specs=P(),
        )(row_block, col_block, row_idx, col_idx)[0]

    return jax.jit(
        step,
        in_shardings=(
            NamedSharding(mesh, row_spec),
            NamedSharding(mesh, col_spec),
            NamedSharding(mesh, flat),
            NamedSharding(mesh, flat),
        ),
        out_shardings=NamedSharding(mesh, P()),
    )


def _range_block_store(
    store: np.ndarray, bounds: np.ndarray, block_rows: int
) -> np.ndarray:
    """Repack contiguous ranges into equal zero-padded blocks.

    Block ``s`` holds ``store[bounds[s]:bounds[s+1]]`` at offset
    ``s * block_rows`` — the host layout whose dim-0 NamedSharding puts
    range ``s`` (and only it) on shard ``s``. Zero rows are harmless: no
    stripe index points at them, and ``popcount(0 & x) == 0``.
    """
    num_shards = len(bounds) - 1
    out = np.zeros((num_shards * block_rows, store.shape[1]), store.dtype)
    for s in range(num_shards):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        out[s * block_rows : s * block_rows + (hi - lo)] = store[lo:hi]
    return out


class Sharded2DExecutor(_StripeScheduleDriver):
    """Device-resident ``sharded_2d`` execute stage for one 2-axis mesh.

    Both slice stores are genuinely ``NamedSharding``-sharded: device
    ``(i, j)`` uploads (once) exactly its row range ``i`` and column range
    ``j`` — the first placement where NEITHER store is replicated, so row
    stores can exceed one device's memory. The ranges come from the
    constructing plan's (typically pair-count-weighted) bounds; ``count``
    re-plans any work list against those fixed bounds, so the stores never
    re-upload — which is exactly where blocks go imbalanced and the
    ``packed`` stripe schedule (see ``_StripeScheduleDriver``) earns its
    fewer psum steps. Pow2 step buckets bound retraces, and index staging
    is double-buffered (step i+1's upload in flight during step i's
    compute).
    """

    def __init__(
        self,
        sbf: SlicedBitmap,
        mesh: Mesh,
        plan: ExecutionPlan | None = None,
        *,
        chunk_pairs: int = 1 << 20,
        double_buffer: bool = True,
        schedule: str = "packed",
    ):
        if schedule not in SCHEDULES:
            raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
        self.schedule = schedule
        if mesh.devices.ndim != 2:
            raise ValueError(
                f"sharded_2d needs a 2-axis mesh, got {mesh.devices.ndim} "
                f"axes {tuple(mesh.axis_names)}"
            )
        self.mesh = mesh
        self.axis_names = tuple(mesh.axis_names)
        self.grid = tuple(int(x) for x in mesh.devices.shape)
        self.num_shards = self.grid[0] * self.grid[1]
        self.words_per_slice = int(sbf.words_per_slice)
        self.chunk_pairs = chunk_pairs
        self.double_buffer = double_buffer
        self._sbf = sbf
        nrow = len(sbf.row_slice_idx)
        ncol = len(sbf.col_slice_idx)
        if plan is None:
            # Worklist-independent fallback: even ranges on both axes. For
            # balanced (weighted) ranges construct from a sharded_2d plan.
            self.row_bounds = even_range_bounds(nrow, self.grid[0])
            self.col_bounds = even_range_bounds(ncol, self.grid[1])
        else:
            if plan.placement != "sharded_2d" or plan.grid != self.grid:
                raise ValueError(
                    f"plan is {plan.placement!r} over grid {plan.grid}, "
                    f"mesh is {self.grid[0]}x{self.grid[1]}"
                )
            self.row_bounds = np.asarray(plan.row_bounds, dtype=np.int64)
            self.col_bounds = np.asarray(plan.col_bounds, dtype=np.int64)
        self.row_shard_rows = _pow2_ceil(
            max(int(np.diff(self.row_bounds).max(initial=0)), 1)
        )
        self.col_shard_rows = _pow2_ceil(
            max(int(np.diff(self.col_bounds).max(initial=0)), 1)
        )
        row_axis, col_axis = self.axis_names
        self.row_store = jax.device_put(
            _range_block_store(
                # tclint: sync-ok(one-time shard repack at executor construction; ROADMAP: device-resident resharding)
                np.asarray(sbf.row_slice_data), self.row_bounds,
                self.row_shard_rows,
            ),
            NamedSharding(mesh, P(row_axis, None)),
        )
        self.col_store = jax.device_put(
            _range_block_store(
                # tclint: sync-ok(one-time shard repack at executor construction; ROADMAP: device-resident resharding)
                np.asarray(sbf.col_slice_data), self.col_bounds,
                self.col_shard_rows,
            ),
            NamedSharding(mesh, P(col_axis, None)),
        )
        self._step = make_sharded_2d_step(mesh, self.axis_names)
        self._validate_int32_floor("blocks", "a smaller grid")

    def _plan(self, wl: Worklist) -> ExecutionPlan:
        """Plan a work list against this executor's FIXED store ranges."""
        return plan_execution(
            self._sbf,
            wl,
            DeviceTopology(num_devices=self.num_shards),
            placement="sharded_2d",
            grid=self.grid,
            chunk_pairs=self.chunk_pairs,
            row_bounds=self.row_bounds,
            col_bounds=self.col_bounds,
        )

    def _check_plan(self, plan: ExecutionPlan) -> None:
        if plan.placement != "sharded_2d":
            raise ValueError(
                f"plan placement {plan.placement!r} is not 'sharded_2d'"
            )
        if plan.grid != self.grid:
            raise ValueError(
                f"plan grid {plan.grid} != mesh grid {self.grid}"
            )
        if not (
            np.array_equal(plan.row_bounds, self.row_bounds)
            and np.array_equal(plan.col_bounds, self.col_bounds)
        ):
            raise ValueError(
                "plan's block-local coordinates assume different store "
                "ranges than this executor's resident blocks; re-plan with "
                "row_bounds/col_bounds pinned to the executor's (or use "
                ".count, which does)"
            )

    def update_stores(self, sbf: SlicedBitmap, row_lanes, col_lanes) -> None:
        """Scatter an ``SBFUpdate``'s lanes into the resident sharded blocks.

        The streaming fast path for sharded placements: lane positions are
        *global* record coordinates (the same ones ``core.sbf.update_sbf``
        emits), so each is remapped to its owner block's local row —
        ``owner * shard_rows + (pos - bounds[owner])`` with the owner found
        by binary search over the resident range bounds — and scattered via
        the shared pow2-bucketed update jit. Only valid when the update did
        not grow either record set (``SBFUpdate.grew`` is False): growth
        changes record positions and hence the range bounds, so callers
        rebuild the executor instead. ``sbf`` becomes the executor's
        planning SBF (its host ptr/slice_idx arrays are unchanged under a
        non-growing update, but its data must match the scattered stores).
        """
        from repro.core.executor import apply_store_lanes
        from repro.core.sbf import UpdateLanes

        if int(sbf.words_per_slice) != self.words_per_slice:
            raise ValueError(
                f"words_per_slice {sbf.words_per_slice} != resident "
                f"{self.words_per_slice}"
            )
        if (
            len(sbf.row_slice_idx) != int(self.row_bounds[-1])
            or len(sbf.col_slice_idx) != int(self.col_bounds[-1])
        ):
            raise ValueError(
                "record counts changed — the SBF grew; rebuild the "
                "sharded executor (bounds and block layout are stale)"
            )

        def remap(lanes, bounds, shard_rows, side):
            if lanes is None or lanes.num_lanes == 0:
                return None
            pos = lanes.pos.astype(np.int64)
            if pos.max(initial=0) >= int(bounds[-1]) or pos.min(initial=0) < 0:
                raise ValueError(
                    f"{side} lane positions exceed the resident record "
                    "range — the SBF grew; rebuild the sharded executor"
                )
            owner = np.searchsorted(bounds, pos, side="right") - 1
            local = owner * shard_rows + (pos - bounds[owner])
            return UpdateLanes(
                pos=local.astype(np.int32),
                word=lanes.word,
                set_mask=lanes.set_mask,
                clear_mask=lanes.clear_mask,
            )

        row_axis, col_axis = self.axis_names
        rl = remap(row_lanes, self.row_bounds, self.row_shard_rows, "row")
        cl = remap(col_lanes, self.col_bounds, self.col_shard_rows, "col")
        if rl is not None:
            self.row_store = jax.device_put(
                apply_store_lanes(self.row_store, rl),
                NamedSharding(self.mesh, P(row_axis, None)),
            )
        if cl is not None:
            self.col_store = jax.device_put(
                apply_store_lanes(self.col_store, cl),
                NamedSharding(self.mesh, P(col_axis, None)),
            )
        self._sbf = sbf

    def _plan_matches_bounds(self, plan: ExecutionPlan | None) -> bool:
        return (
            plan is not None
            and plan.placement == "sharded_2d"
            and plan.grid == self.grid
            and np.array_equal(plan.row_bounds, self.row_bounds)
            and np.array_equal(plan.col_bounds, self.col_bounds)
        )

    def count_async(
        self, wl: Worklist, plan: ExecutionPlan | None = None
    ) -> CountFuture:
        """``count`` with the final host readback deferred to ``result()``."""
        if self._plan_matches_bounds(plan):
            return self.count_plan_async(plan)
        return self.count_plan_async(self._plan(wl))

    def count(self, wl: Worklist, plan: ExecutionPlan | None = None) -> int:
        """Count a work list against the resident sharded stores.

        A pre-built ``plan`` is used as-is when its ranges match the
        resident blocks (skips re-planning); otherwise — e.g. a fresh
        weighted plan for a new work list on a pooled executor — ``wl`` is
        re-planned against the executor's FIXED bounds, trading a little
        balance for keeping the uploaded shards and traced step.
        """
        return self.count_async(wl, plan).result()


# Bounded cache of sharded executors for the one-shot APIs, keyed by store
# *content* (like core.executor.ExecutorPool) so repeated counts of the same
# graph hit even though tcim_count* rebuilds the SBF object per call —
# reusing the uploaded shards and the traced step instead of paying both.
# Shared by the 1-D and 2-D executors (their key tuples cannot collide).
_SHARDED_CACHE: collections.OrderedDict = collections.OrderedDict()
_SHARDED_CACHE_MAX = 4


def pooled_sharded_executor(
    sbf: SlicedBitmap,
    mesh: Mesh,
    *,
    chunk_pairs: int = 1 << 20,
    double_buffer: bool = True,
    schedule: str = "packed",
) -> ShardedColsExecutor:
    from repro.core.executor import sbf_content_key

    # EVERY config knob is part of the key — a pooled hit must never hand
    # back an executor with different buffering or scheduling than requested.
    key = (sbf_content_key(sbf), mesh, chunk_pairs, double_buffer, schedule)
    entry = _SHARDED_CACHE.get(key)
    if entry is not None:
        _SHARDED_CACHE.move_to_end(key)
        return entry
    ex = ShardedColsExecutor(
        sbf,
        mesh,
        chunk_pairs=chunk_pairs,
        double_buffer=double_buffer,
        schedule=schedule,
    )
    _SHARDED_CACHE[key] = ex
    _SHARDED_CACHE.move_to_end(key)
    while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:
        _SHARDED_CACHE.popitem(last=False)
    return ex


def pooled_sharded_2d_executor(
    sbf: SlicedBitmap,
    mesh: Mesh,
    plan: ExecutionPlan,
    *,
    chunk_pairs: int = 1 << 20,
    double_buffer: bool = True,
    schedule: str = "packed",
) -> Sharded2DExecutor:
    """Cached ``Sharded2DExecutor`` for (store content, mesh, grid, config).

    The bounds are deliberately NOT part of the key: a hit means the graph's
    stores are already resident under some (earlier-planned) ranges, and
    re-uploading both NamedSharding-sharded stores to chase a new work
    list's slightly-better-balanced cuts costs far more than it saves —
    callers route new work lists through ``count(wl, plan)``, which falls
    back to the resident fixed bounds when the plan's ranges differ. The
    config knobs (``double_buffer``, ``schedule``) ARE keyed: they change
    runtime behaviour, not the resident stores, and a hit must honor them.
    """
    from repro.core.executor import sbf_content_key

    key = (
        sbf_content_key(sbf), mesh, plan.grid, chunk_pairs, double_buffer,
        schedule,
    )
    entry = _SHARDED_CACHE.get(key)
    if entry is not None:
        _SHARDED_CACHE.move_to_end(key)
        return entry
    ex = Sharded2DExecutor(
        sbf,
        mesh,
        plan,
        chunk_pairs=chunk_pairs,
        double_buffer=double_buffer,
        schedule=schedule,
    )
    _SHARDED_CACHE[key] = ex
    _SHARDED_CACHE.move_to_end(key)
    while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:
        _SHARDED_CACHE.popitem(last=False)
    return ex


def clear_sharded_executor_cache() -> None:
    """Release every cached sharded executor (frees the NamedSharding-sharded
    slice stores — sharded graphs are exactly the ones big enough to care)."""
    _SHARDED_CACHE.clear()


def distributed_tc_count_async(
    sbf: SlicedBitmap,
    wl: Worklist,
    mesh: Mesh,
    *,
    placement: str = "replicated",
    max_step_pairs: int | None = None,
    schedule: str = "packed",
) -> CountFuture:
    """``distributed_tc_count`` with the host readback deferred.

    Every placement dispatches all of its psum steps before returning — the
    replicated path included, which used to sync ``int(step(...))`` per
    stripe chunk; its per-stripe device scalars now ride the returned
    ``CountFuture`` and are summed exactly (host ints) at ``result()``.
    Fleet callers overlap graph i's close with graph i+1's build and
    stripe assembly on ANY placement.

    Like every async path in this repo (``Executor.execute_indices_async``,
    the sharded ``count_plan_async``), all steps' index uploads may be in
    flight at once: ``max_step_pairs`` bounds the per-step compute and the
    psum's int32 worst case, while total *staging* memory grows with the
    step count (8 index bytes per lane per side). Callers serving work
    lists with very many steps under tight device memory should sync in
    batches (loop sub-worklists through the blocking API) instead.
    """
    if placement not in TC_PLACEMENTS:
        raise ValueError(f"placement {placement!r} not in {TC_PLACEMENTS}")
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
    chunk = max_step_pairs if max_step_pairs is not None else 1 << 20
    if placement == "sharded_cols":
        return pooled_sharded_executor(
            sbf, mesh, chunk_pairs=chunk, schedule=schedule
        ).count_async(wl)
    if placement == "sharded_2d":
        grid = tuple(int(x) for x in mesh.devices.shape)
        if len(grid) != 2:
            raise ValueError(
                f"placement 'sharded_2d' needs a 2-axis mesh, got "
                f"{len(grid)} axes {tuple(mesh.axis_names)}"
            )
        plan = plan_execution(
            sbf,
            wl,
            DeviceTopology(num_devices=grid[0] * grid[1]),
            placement="sharded_2d",
            grid=grid,
            chunk_pairs=chunk,
        )
        ex = pooled_sharded_2d_executor(
            sbf, mesh, plan, chunk_pairs=chunk, schedule=schedule
        )
        return ex.count_async(wl, plan)
    if wl.num_pairs == 0:
        # Match the sharded paths' empty-schedule guard: nothing to count,
        # so never pad, upload, or dispatch a psum step for it.
        return CountFuture([])
    axis_names = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    step = make_tc_step(mesh, axis_names)
    row_store = jnp.asarray(sbf.row_slice_data)
    col_store = jnp.asarray(sbf.col_slice_data)
    max_pairs = max(INT32_SAFE_WORDS // max(sbf.words_per_slice, 1), 1)
    if max_step_pairs is not None:
        max_pairs = max(min(max_pairs, max_step_pairs), 1)
    totals = []
    for start in range(0, max(wl.num_pairs, 1), max_pairs):
        sub = _slice_worklist(wl, start, start + max_pairs)
        row_idx, col_idx = shard_worklist(sub, n_dev)
        totals.append(
            step(
                row_store,
                col_store,
                jnp.asarray(row_idx.reshape(-1)),
                jnp.asarray(col_idx.reshape(-1)),
            )
        )
    return CountFuture(totals)


def distributed_tc_count(
    sbf: SlicedBitmap,
    wl: Worklist,
    mesh: Mesh,
    *,
    placement: str = "replicated",
    max_step_pairs: int | None = None,
    schedule: str = "packed",
) -> int:
    """Execute the distributed count on an actual mesh (test/production path).

    Per-shard partials AND their psum accumulate in int32 (x64 is off), so
    the work list is split into stripes whose worst-case count provably fits
    int32 — one step per stripe, per-stripe totals summed exactly on the
    host (the distributed analogue of core.executor's escape hatch). Work
    lists under the bound take exactly one step, as before; either way the
    steps are all dispatched before the single host sync (see
    ``distributed_tc_count_async``, which defers even that).

    ``placement='sharded_cols'`` runs the column-sharded path instead: the
    column store is NamedSharding-sharded over the mesh and the work list is
    owner-grouped per shard (see ``ShardedColsExecutor``).
    ``placement='sharded_2d'`` shards BOTH stores over a 2-axis mesh with
    pair-count-weighted ranges (see ``Sharded2DExecutor``). Long-lived
    callers should construct the executors themselves and reuse them.

    ``max_step_pairs`` additionally bounds the pairs per psum step below the
    int32-safety budget (the caller's memory bound, e.g. the engine's
    ``chunk_pairs``). ``schedule`` picks the sharded paths' stripe
    scheduling policy (``packed`` default / ``lockstep`` baseline; the
    replicated path has a single stripe, so it does not apply there). All
    placements run the fused jnp mirror inside shard_map — Executor modes
    don't apply here.
    """
    return distributed_tc_count_async(
        sbf,
        wl,
        mesh,
        placement=placement,
        max_step_pairs=max_step_pairs,
        schedule=schedule,
    ).result()


def _slice_worklist(wl: Worklist, start: int, stop: int) -> Worklist:
    return Worklist(
        pair_edge=wl.pair_edge[start:stop],
        pair_row_pos=wl.pair_row_pos[start:stop],
        pair_col_pos=wl.pair_col_pos[start:stop],
        m_edges=wl.m_edges,
        n_slices=wl.n_slices,
    )
