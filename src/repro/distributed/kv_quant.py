"""int8 KV-cache quantization (serving memory optimization).

The decode_32k cells carry 0.7-5.4 GB/chip of bf16 KV cache; int8 halves it
(and halves the decode memory-roofline term, which is cache-read-bound).
Per-(position, head) symmetric scales keep the logit error at the ~1e-2
level — the standard serving trade (see tests/test_kv_quant.py).

API mirrors a cache leaf: quantize [B,S,K,hd] bf16 -> (int8 values,
f32 scales [B,S,K,1]); attention dequantizes blockwise. Integration is a
config-level follow-up (cache dtype plumbing); the utility + error bounds
are validated here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kv_quantize", "kv_dequantize", "kv_cache_bytes"]


def kv_quantize(kv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[..., hd] -> (int8 [..., hd], f32 scale [..., 1]); symmetric per-row."""
    f = kv.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(f), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def kv_cache_bytes(b: int, s: int, kv_heads: int, hd: int, layers: int,
                   quantized: bool) -> int:
    """Per-cache-side byte footprint (x2 for K and V)."""
    per_tok = kv_heads * (hd * (1 if quantized else 2) + (4 if quantized else 0))
    return b * s * per_tok * layers
