"""Gradient compression for the cross-pod (DCN) all-reduce.

Pods are pure data parallelism: gradients are averaged across pods once per
step over links ~10x slower than ICI. int8 quantization with per-tensor
scales + error feedback (Seide et al.; 1-bit Adam lineage) cuts that traffic
4x vs f32 (2x vs bf16) with no measurable convergence change at these
scales; the residual buffer makes the quantization error telescope instead
of accumulate.

``compressed_psum_mean``: shard_map-based mean over an axis where each
participant transmits int8: quantize -> psum(int32) -> dequantize. Exactness
property (tested): with error feedback, sum over steps of (decoded - true)
stays bounded by one quantization step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_mean", "ef_update"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_update(grad: jax.Array, residual: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback step: quantize (grad + residual), carry the new error.

    Returns (q, scale, new_residual).
    """
    target = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    decoded = dequantize_int8(q, scale)
    return q, scale, target - decoded


def compressed_psum_mean(stacked_grads, mesh, axis: str):
    """Mean over mesh axis ``axis`` with int8 on the wire.

    ``stacked_grads``: pytree whose leaves have a leading dim equal to the
    axis size — entry i is rank i's local gradient (the manual-DP layout of
    the cross-pod reduce). Scheme: pmax the amax first (one scalar
    collective), quantize everyone against the SHARED scale, psum in int32
    (exact), dequantize. Returns the stacked tree with every entry holding
    the identical mean (replicated per rank).
    """
    from jax.sharding import PartitionSpec as P

    def local(g):
        def one(leaf):
            x = leaf[0].astype(jnp.float32)  # this rank's shard
            amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            return (total.astype(jnp.float32) * scale / n).astype(leaf.dtype)[None]

        return jax.tree.map(one, g)

    spec = jax.tree.map(
        lambda leaf: P(axis, *([None] * (leaf.ndim - 1))), stacked_grads
    )
    return shard_map(
        local, mesh=mesh, in_specs=(spec,), out_specs=spec
    )(stacked_grads)
