"""Sharding rules for the LM stack on the production mesh.

Layout summary (mesh (pod, data, model); single-pod drops 'pod'):

  params/optimizer  ZeRO-3: one non-TP dim over 'data', TP dims over 'model'
                    (from the schema in models/*.py); replicated across pods
                    (pods are pure DP; gradient all-reduce crosses pods once
                    per step over DCN — the classic multi-slice layout).
  batch             batch dim over ('pod','data') when divisible, else
                    replicated (e.g. long_500k's batch=1).
  KV caches         *sequence* dim over 'model' (flash-decoding layout: the
                    per-step softmax combine is a tiny collective, vs.
                    all-gathering KV or replicating the cache), batch over dp.
  SSM states        heads over 'model', batch over dp.
  logits            vocab over 'model' when divisible (loss computes against
                    sharded logits; GSPMD inserts the logsumexp reductions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.ctx import _shrink, arch_profile, rules_for
from repro.models.config import ModelConfig
from repro.models.model import model_param_specs
from repro.models.params import param_specs as schema_param_specs

__all__ = [
    "dp_axes",
    "dp_size",
    "batch_spec_tree",
    "cache_spec_tree",
    "train_state_specs",
    "logits_spec",
    "named_tree",
]


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    names = dp_axes(mesh)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for n in names:
        out *= shape[n]
    return out


def _tp_size(mesh: Mesh) -> int:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return shape.get("model", 1)


def _b(mesh: Mesh, batch: int):
    """Batch-dim spec entry: dp axes if divisible, else replicated."""
    return dp_axes(mesh) if batch % dp_size(mesh) == 0 else None


def batch_spec_tree(cfg: ModelConfig, mesh: Mesh, batch: dict) -> dict:
    """PartitionSpecs for a train/prefill batch dict (keyed like the batch).

    'dp'-profile archs (heads not divisible by the model axis) spread the
    batch over the model axis too when it divides — pure data parallelism.
    """
    rules = rules_for(cfg, mesh)
    out = {}
    for k, v in batch.items():
        bdim = _shrink(mesh, rules["dp"], v.shape[0])
        out[k] = P(bdim, *([None] * (v.ndim - 1)))
    return out


def cache_spec_tree(cfg: ModelConfig, mesh: Mesh, cache) -> dict:
    """Specs mirroring init_cache's structure. Seq over 'model', batch dp."""
    tp = _tp_size(mesh)

    def spec_for(path_keys: tuple[str, ...], x) -> P:
        key = path_keys[-1]
        if key in ("k", "v"):  # [L, B, S, K, hd] or vlm [G, sp, B, S, K, hd]
            lead = x.ndim - 4  # stacked layer/group dims before [B, S, K, hd]
            b, s = x.shape[lead], x.shape[lead + 1]
            return P(
                *([None] * lead),
                _b(mesh, b),
                "model" if s % tp == 0 else None,
                None,
                None,
            )
        if key in ("shared_k", "shared_v"):  # [A, B, S, K, hd]
            b, s = x.shape[1], x.shape[2]
            return P(None, _b(mesh, b), "model" if s % tp == 0 else None, None, None)
        if key in ("xk", "xv"):  # [G, B, n_img, K, hd]
            return P(None, _b(mesh, x.shape[1]), None, None, None)
        if key in ("ckv", "krope"):  # [L, B, S, r]
            b, s = x.shape[1], x.shape[2]
            return P(None, _b(mesh, b), "model" if s % tp == 0 else None, None)
        if key in ("conv_x", "conv_b", "conv_c"):  # [L, B, w-1, C]
            c = x.shape[-1]
            return P(None, _b(mesh, x.shape[1]), None, "model" if c % tp == 0 else None)
        if key == "ssm":  # [L, B, H, N, Pd]
            h = x.shape[2]
            return P(
                None, _b(mesh, x.shape[1]), "model" if h % tp == 0 else None, None, None
            )
        raise KeyError(f"unknown cache leaf {path_keys}")

    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(tuple(k.key for k in path), x), cache
    )


def _first_divisible_dim_spec(shape: tuple, size: int) -> P:
    """Shard the first dim divisible by ``size`` over 'data' (ZeRO-1)."""
    entries = [None] * len(shape)
    for i, d in enumerate(shape):
        if d % size == 0 and d > 0:
            entries[i] = "data"
            break
    return P(*entries)


def train_state_specs(cfg: ModelConfig):
    """(param_specs, opt_specs, grad_specs).

    tp profile: ZeRO-3 — params/moments/grads all shard ('data' x 'model').
    dp profile: params fully REPLICATED (pure data parallelism: no layout
    conflicts anywhere in fwd/bwd), optimizer moments and the gradient
    accumulator ZeRO-1-sharded over 'data' (the per-step param all-gather is
    the classic ZeRO-1 trade).
    """
    from repro.distributed.constants import DATA_AXIS_SIZE
    from repro.models.model import model_schema
    from repro.models.params import ParamDef

    schema = model_schema(cfg)
    if arch_profile(cfg) == "tp":
        if getattr(cfg, "zero3", True):
            pspecs = model_param_specs(cfg)
            opt = {"m": pspecs, "v": pspecs, "step": P()}
            return pspecs, opt, pspecs
        # TP/EP-only storage: params replicated over 'data' (no per-layer
        # weight gathers); moments/grads keep the ZeRO sharding over 'data'.
        pspecs = schema_param_specs(
            schema, {"fsdp": None, "tp": "model", "vocab": "model", None: None}
        )
        zspecs = model_param_specs(cfg)  # fsdp->data on the storage dim
        opt = {"m": zspecs, "v": zspecs, "step": P()}
        return pspecs, opt, zspecs
    pspecs = jax.tree.map(
        lambda d: P(*([None] * len(d.shape))),
        schema,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )
    zero1 = jax.tree.map(
        lambda d: _first_divisible_dim_spec(d.shape, DATA_AXIS_SIZE),
        schema,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )
    opt = {"m": zero1, "v": zero1, "step": P()}
    return pspecs, opt, zero1


def logits_spec(cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    tp = _tp_size(mesh)
    return P(_b(mesh, batch), None, "model" if cfg.vocab % tp == 0 else None)


def named_tree(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
