"""Resilient sharded counts: checkpointed cursors + elastic shrink-remesh.

The fault-tolerance story the execute layer earns from TCIM's algebra: the
count is a commutative integer monoid over disjoint pair stripes, so

  * *progress* is a tiny serializable cursor — the committed total plus
    ``StripeSchedule.cursor_after`` per-shard pair offsets (saved every
    ``checkpoint_every`` psum steps through the async CheckpointManager);
  * *state* is one per-attempt snapshot — the SBF stores plus the attempt's
    remaining worklist in store-global coordinates;
  * *recovery* is a re-partition — ``tc_remesh_plan`` shrinks the
    ``(rows, cols)`` owner grid to the surviving device count,
    ``plan_execution`` re-balances the uncounted pairs onto it
    (``balance_grid_bounds`` under the hood), and the resumed count is
    bit-identical because no pair is lost or double-counted.

Layout of a checkpoint root (two retention domains, so frequent cursor
saves never garbage-collect the heavy store snapshot):

    <dir>/stores/step_<attempt>/   SBF stores + worklist, once per attempt
    <dir>/cursor/step_<attempt*1e6 + step>/   cursor, every K steps

Cursor step numbers are attempt-strided: attempt 1's step 8 must not be
shadowed by attempt 0's step 16 under ``latest_step`` discovery.

``resilient_tc_count`` drives the whole loop in-process (inject failures
with ``runtime.fault.FailureInjector``, flag stragglers with
``StragglerMonitor``); ``resume_tc_count`` restarts a killed process from
nothing but the checkpoint directory and a mesh of surviving devices.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.store import (
    CheckpointManager,
    list_steps,
    load_checkpoint,
)
from repro.core.plan import (
    DeviceTopology,
    ExecutionPlan,
    plan_execution,
    remaining_worklist,
)
from repro.core.sbf import SlicedBitmap, Worklist
from repro.distributed.tc import Sharded2DExecutor
from repro.runtime.elastic import tc_remesh_plan
from repro.runtime.fault import CountInterrupted

__all__ = [
    "ATTEMPT_STRIDE",
    "TCCheckpoint",
    "RecoveryState",
    "ResilienceConfig",
    "resilient_tc_count",
    "resume_tc_count",
]

# Cursor checkpoints are numbered attempt * ATTEMPT_STRIDE + step so that
# discovery by max-step never resolves to a *previous* attempt's deeper
# step after a remesh shortens the schedule.
ATTEMPT_STRIDE = 1_000_000


@dataclasses.dataclass(frozen=True)
class RecoveryState:
    """Everything ``load_latest`` reconstructs from disk — enough to rebuild
    the interrupted attempt's plan deterministically and slice off the
    uncounted tail of every stripe."""

    sbf: SlicedBitmap
    worklist: Worklist  # the snapshot attempt's FULL worklist (global coords)
    placement: str
    grid: tuple[int, int]
    chunk_pairs: int
    schedule: str
    row_bounds: np.ndarray | None
    col_bounds: np.ndarray | None
    attempt: int
    committed_total: int
    committed_step: int
    shard_cursors: tuple[int, ...] | None  # None: no commit this attempt yet


class TCCheckpoint:
    """Checkpoint root for a resumable count: ``stores/`` + ``cursor/``.

    Two ``CheckpointManager``s with separate retention — the heavy store
    snapshot (one per attempt, ``keep_last=1``) must survive arbitrarily
    many light cursor commits (``keep_last=keep_last``). Both saves are
    async: the device->host gather happens at the call, file I/O on the
    writer thread overlaps subsequent psum steps.
    """

    _SBF_KEYS = (
        "row_ptr", "row_slice_idx", "row_slice_data",
        "col_ptr", "col_slice_idx", "col_slice_data",
    )
    _SNAPSHOT_KEYS = _SBF_KEYS + (
        "wl_row_pos", "wl_col_pos", "row_bounds", "col_bounds",
    )

    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.directory = Path(directory)
        self.stores = CheckpointManager(self.directory / "stores", keep_last=1)
        self.cursor = CheckpointManager(
            self.directory / "cursor", keep_last=keep_last
        )

    def save_snapshot(
        self,
        sbf: SlicedBitmap,
        plan: ExecutionPlan,
        *,
        attempt: int,
        base_total: int,
        schedule: str = "packed",
    ) -> None:
        """Persist the attempt's stores + full worklist (async), once: a
        snapshot already durable for this (or a later) attempt is a no-op,
        so repeated counts against one checkpointer pay only cursor I/O."""
        latest = self.stores.latest_step()
        if latest is not None and latest >= attempt:
            return
        wl = remaining_worklist(plan)  # plan order, store-global coords
        has_rb = plan.row_bounds is not None
        has_cb = plan.col_bounds is not None
        tree = {
            "row_ptr": np.asarray(sbf.row_ptr),
            "row_slice_idx": np.asarray(sbf.row_slice_idx),
            # tclint: sync-ok(checkpoint snapshot gathers stores to host by design)
            "row_slice_data": np.asarray(sbf.row_slice_data),
            "col_ptr": np.asarray(sbf.col_ptr),
            "col_slice_idx": np.asarray(sbf.col_slice_idx),
            # tclint: sync-ok(checkpoint snapshot gathers stores to host by design)
            "col_slice_data": np.asarray(sbf.col_slice_data),
            "wl_row_pos": np.asarray(wl.pair_row_pos),
            "wl_col_pos": np.asarray(wl.pair_col_pos),
            "row_bounds": np.asarray(
                plan.row_bounds if has_rb else np.zeros(0, np.int64)
            ),
            "col_bounds": np.asarray(
                plan.col_bounds if has_cb else np.zeros(0, np.int64)
            ),
        }
        extra = {
            "attempt": int(attempt),
            "base_total": int(base_total),
            "slice_bits": int(sbf.slice_bits),
            "n": int(sbf.n),
            "n_slices": int(sbf.n_slices),
            "placement": plan.placement,
            "grid": [int(plan.grid[0]), int(plan.grid[1])],
            "chunk_pairs": int(plan.chunk_pairs),
            "schedule": schedule,
            "has_row_bounds": bool(has_rb),
            "has_col_bounds": bool(has_cb),
        }
        self.stores.save_async(attempt, tree, extra)

    def save_cursor(
        self,
        attempt: int,
        step: int,
        shard_cursors,
        total: int,
        plan: ExecutionPlan,
    ) -> None:
        """Persist one committed cursor (async, attempt-strided step)."""
        tree = {"shard_cursors": np.asarray(shard_cursors, np.int64)}
        extra = {
            "attempt": int(attempt),
            "committed_step": int(step),
            "committed_total": int(total),
            "grid": [int(plan.grid[0]), int(plan.grid[1])],
        }
        self.cursor.save_async(attempt * ATTEMPT_STRIDE + step, tree, extra)

    def wait(self) -> None:
        """Join in-flight writes (re-raising a failed one, see
        ``CheckpointManager.wait``)."""
        self.stores.wait()
        self.cursor.wait()

    def peek(self) -> dict:
        """The latest snapshot's manifest ``extra`` — no leaf I/O. Recovery
        reads the old grid here before deciding the new mesh shape."""
        self.wait()
        step = self.stores.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no committed store snapshot under {self.stores.directory}"
            )
        manifest = json.loads(
            (self.stores.directory / f"step_{step:08d}" / "manifest.json")
            .read_text()
        )
        return manifest["extra"]

    def load_latest(self, mesh: Mesh | None = None) -> RecoveryState:
        """Reconstruct the latest attempt's state from disk.

        With ``mesh``, the snapshot leaves are restored straight onto it as
        replicated jax arrays (``load_checkpoint(shardings=...)`` with
        ``NamedSharding(mesh, P())``) — the elastic-restore path, placing
        the stores on the *new* device set; without it, host numpy.
        The cursor is the deepest committed one OF THE SNAPSHOT'S ATTEMPT
        (attempt-strided numbering; a younger attempt's stray cursor with
        no matching snapshot is ignored — it only ever means the snapshot
        write lost the race to a crash, and the previous attempt's state
        is the last consistent one).
        """
        self.wait()
        tree_like = {k: 0 for k in self._SNAPSHOT_KEYS}
        shardings = None
        if mesh is not None:
            rep = NamedSharding(mesh, P())
            shardings = {k: rep for k in self._SNAPSHOT_KEYS}
        tree, attempt, extra = load_checkpoint(
            self.stores.directory, tree_like, shardings=shardings
        )
        sbf = SlicedBitmap(
            slice_bits=int(extra["slice_bits"]),
            n=int(extra["n"]),
            n_slices=int(extra["n_slices"]),
            row_ptr=tree["row_ptr"],
            row_slice_idx=tree["row_slice_idx"],
            row_slice_data=tree["row_slice_data"],
            col_ptr=tree["col_ptr"],
            col_slice_idx=tree["col_slice_idx"],
            col_slice_data=tree["col_slice_data"],
        )
        wl_row = np.asarray(tree["wl_row_pos"])
        wl = Worklist(
            pair_edge=np.zeros(len(wl_row), np.int64),
            pair_row_pos=wl_row,
            pair_col_pos=np.asarray(tree["wl_col_pos"]),
            m_edges=0,
            n_slices=int(extra["n_slices"]),
        )
        committed_total = int(extra["base_total"])
        committed_step = 0
        cursors: tuple[int, ...] | None = None
        mine = [
            s for s in list_steps(self.cursor.directory)
            if s // ATTEMPT_STRIDE == attempt
        ]
        if mine:
            ctree, _, cextra = load_checkpoint(
                self.cursor.directory, {"shard_cursors": 0}, step=max(mine)
            )
            committed_total = int(cextra["committed_total"])
            committed_step = int(cextra["committed_step"])
            cursors = tuple(
                int(c) for c in np.asarray(ctree["shard_cursors"])
            )
        return RecoveryState(
            sbf=sbf,
            worklist=wl,
            placement=extra["placement"],
            grid=(int(extra["grid"][0]), int(extra["grid"][1])),
            chunk_pairs=int(extra["chunk_pairs"]),
            schedule=extra.get("schedule", "packed"),
            row_bounds=(
                np.asarray(tree["row_bounds"])
                if extra.get("has_row_bounds")
                else None
            ),
            col_bounds=(
                np.asarray(tree["col_bounds"])
                if extra.get("has_col_bounds")
                else None
            ),
            attempt=int(attempt),
            committed_total=committed_total,
            committed_step=committed_step,
            shard_cursors=cursors,
        )


@dataclasses.dataclass
class ResilienceConfig:
    """Policy knobs for ``resilient_tc_count`` / ``tcim_count(resilience=)``.

    ``checkpoint_every`` trades steps-replayed-on-failure against commit
    overhead (each commit is one stacked scalar readback + an async cursor
    write; the CI gate holds the cadence-8 overhead under 10%).
    ``lose_devices`` is the simulated blast radius per failure (0 = the
    failed device is replaced: recover on the same-size grid). A sequence
    gives the blast radius per *successive* failure — ``(4, 2, 1)`` soaks a
    cascading 8 -> 4 -> 2 -> 1 shrink; failures past the end reuse the last
    entry.
    ``monitor`` opts into per-step timing (blocks each step — the
    observability tradeoff) and, with ``monitor_interrupts``, routes a
    straggler flag through the same checkpoint-and-remesh path.
    """

    checkpoint_dir: str | Path
    checkpoint_every: int = 8
    keep_last: int = 3
    injector: object | None = None  # runtime.fault.FailureInjector
    monitor: object | None = None  # runtime.fault.StragglerMonitor
    monitor_interrupts: bool = True
    max_failures: int = 2
    lose_devices: int | tuple[int, ...] = 1

    def blast_radius(self, failure: int) -> int:
        """Devices lost by the ``failure``-th interrupt (1-based)."""
        lose = self.lose_devices
        if isinstance(lose, int):
            return lose
        seq = tuple(int(x) for x in lose)
        if not seq:
            return 0
        return seq[min(failure, len(seq)) - 1]

    def for_request(self, request_id: int) -> "ResilienceConfig":
        """A copy rooted at a per-request checkpoint subdirectory.

        The serving layer runs many sharded solos against one configured
        resilience policy; giving each request its own ``req_<id>`` subtree
        keeps their cursors/snapshots from clobbering each other while
        sharing every other knob (injector included — deliberately, so a
        soak's step counter spans the whole drain)."""
        return dataclasses.replace(
            self, checkpoint_dir=Path(self.checkpoint_dir) / f"req_{request_id}"
        )


def _build_executor(
    sbf: SlicedBitmap,
    wl: Worklist,
    mesh: Mesh,
    *,
    chunk_pairs: int,
    schedule: str,
) -> tuple[Sharded2DExecutor, ExecutionPlan]:
    grid = tuple(int(x) for x in mesh.devices.shape)
    plan = plan_execution(
        sbf,
        wl,
        DeviceTopology(num_devices=grid[0] * grid[1]),
        placement="sharded_2d",
        grid=grid,
        chunk_pairs=chunk_pairs,
    )
    ex = Sharded2DExecutor(
        sbf, mesh, plan, chunk_pairs=chunk_pairs, schedule=schedule
    )
    return ex, plan


def _recover(
    ckpt: TCCheckpoint, devices: list, axis_names: tuple[str, str]
) -> tuple[Sharded2DExecutor, ExecutionPlan, int, int]:
    """Rebuild an interrupted count from disk onto the surviving devices.

    Deterministic in two halves: the interrupted attempt's plan is rebuilt
    from the snapshot worklist with its bounds PINNED (split="fixed" —
    same cuts, same stripes, same pair order), so the committed cursors
    slice off exactly the uncounted tail; that tail, lifted to store-global
    coordinates, is then re-balanced as a fresh weighted plan on the
    shrunk ``tc_remesh_plan`` grid. Returns
    ``(executor, plan, base_total, attempt)`` for the next attempt.
    """
    extra = ckpt.peek()
    if extra["placement"] != "sharded_2d":
        raise ValueError(
            f"elastic recovery supports sharded_2d snapshots, got "
            f"{extra['placement']!r}"
        )
    old_grid = (int(extra["grid"][0]), int(extra["grid"][1]))
    rp = tc_remesh_plan(old_grid, len(devices), axis_names)
    if not rp.ok:
        raise RuntimeError(
            f"no viable remesh from grid {old_grid} onto {len(devices)} "
            f"devices: {'; '.join(rp.reasons)}"
        )
    rows, cols = rp.new_shape
    new_mesh = Mesh(
        np.asarray(devices[: rows * cols], dtype=object).reshape(rows, cols),
        axis_names,
    )
    state = ckpt.load_latest(mesh=new_mesh)
    old_plan = plan_execution(
        state.sbf,
        state.worklist,
        DeviceTopology(num_devices=old_grid[0] * old_grid[1]),
        placement="sharded_2d",
        grid=old_grid,
        chunk_pairs=state.chunk_pairs,
        row_bounds=state.row_bounds,
        col_bounds=state.col_bounds,
    )
    rem = remaining_worklist(
        old_plan, state.shard_cursors, n_slices=state.sbf.n_slices
    )
    ex, plan = _build_executor(
        state.sbf,
        rem,
        new_mesh,
        chunk_pairs=state.chunk_pairs,
        schedule=state.schedule,
    )
    return ex, plan, state.committed_total, state.attempt + 1


def resilient_tc_count(
    sbf: SlicedBitmap,
    wl: Worklist,
    mesh: Mesh,
    config: ResilienceConfig,
    *,
    chunk_pairs: int = 1 << 20,
    schedule: str = "packed",
) -> tuple[int, dict]:
    """A sharded_2d count that survives device loss, bit-identically.

    Runs ``count_plan_resumable`` with the config's checkpoint cadence;
    on ``CountInterrupted`` (injected/real failure, or straggler flag)
    drops ``config.lose_devices`` devices, shrinks the grid via
    ``tc_remesh_plan``, restores stores + cursor FROM THE CHECKPOINT (not
    in-memory state — the same code path a process restart takes), and
    resumes the uncounted pairs on the new mesh. At most
    ``config.max_failures`` recoveries; further interrupts re-raise.

    Returns ``(total, info)``: ``info`` records attempts, failures,
    remeshes (with steps replayed), checkpoint commits, recovery
    wall-clock, and the final grid.
    """
    if mesh.devices.ndim != 2:
        raise ValueError(
            f"resilient counts need a 2-axis mesh, got {mesh.devices.ndim} "
            f"axes {tuple(mesh.axis_names)}"
        )
    axis_names = tuple(mesh.axis_names)
    devices = list(mesh.devices.reshape(-1))
    ckpt = TCCheckpoint(config.checkpoint_dir, keep_last=config.keep_last)
    ex, plan = _build_executor(
        sbf, wl, mesh, chunk_pairs=chunk_pairs, schedule=schedule
    )
    attempt = 0
    base_total = 0
    info: dict = {
        "failures": 0,
        "remeshes": [],
        "steps_replayed": 0,
        "checkpoints": 0,
        "recovery_s": 0.0,
        "grid": list(ex.grid),
        "checkpoint_dir": str(ckpt.directory),
    }
    while True:
        try:
            total, cinfo = ex.count_plan_resumable(
                plan,
                checkpoint_every=config.checkpoint_every,
                checkpointer=ckpt,
                injector=config.injector,
                monitor=config.monitor,
                monitor_interrupts=config.monitor_interrupts,
                base_total=base_total,
                attempt=attempt,
            )
            info["checkpoints"] += cinfo["checkpoints"]
            info["steps"] = cinfo["steps"]
            if "step_ewma_s" in cinfo:
                info["step_ewma_s"] = cinfo["step_ewma_s"]
            info["attempts"] = attempt + 1
            ckpt.wait()
            return total, info
        except CountInterrupted as ci:
            info["failures"] += 1
            if info["failures"] > config.max_failures:
                raise
            t0 = time.perf_counter()
            lose = config.blast_radius(info["failures"])
            if lose > 0:
                devices = devices[: len(devices) - lose]
            if not devices:
                raise
            ex, plan, base_total, attempt = _recover(
                ckpt, devices, axis_names
            )
            if config.monitor is not None:
                config.monitor.reset()
            info["remeshes"].append(
                {
                    "reason": ci.reason,
                    "failed_step": ci.failed_step,
                    "committed_step": ci.committed_step,
                    "replayed": ci.steps_replayed,
                    "grid": list(ex.grid),
                }
            )
            info["steps_replayed"] += ci.steps_replayed
            info["grid"] = list(ex.grid)
            info["recovery_s"] += time.perf_counter() - t0


def resume_tc_count(
    checkpoint_dir: str | Path,
    mesh: Mesh,
    *,
    checkpoint_every: int = 8,
    keep_last: int = 3,
    injector=None,
    monitor=None,
) -> tuple[int, dict]:
    """Restart a killed count from nothing but its checkpoint directory.

    The process-crash recovery path: rebuilds stores, worklist, and the
    last committed cursor from disk, re-partitions the uncounted pairs
    onto ``mesh``'s devices (grid re-derived by ``tc_remesh_plan``; the
    mesh's own shape only contributes axis names + device set), and runs
    the remainder under the same checkpointing. A count that had already
    finished resumes into an empty schedule and simply returns its total.
    """
    ckpt = TCCheckpoint(checkpoint_dir, keep_last=keep_last)
    axis_names = tuple(mesh.axis_names)
    if len(axis_names) != 2:
        raise ValueError(
            f"resume needs a 2-axis mesh, got axes {axis_names}"
        )
    ex, plan, base_total, attempt = _recover(
        ckpt, list(mesh.devices.reshape(-1)), axis_names
    )
    total, cinfo = ex.count_plan_resumable(
        plan,
        checkpoint_every=checkpoint_every,
        checkpointer=ckpt,
        injector=injector,
        monitor=monitor,
        base_total=base_total,
        attempt=attempt,
    )
    ckpt.wait()
    return total, {
        "attempt": attempt,
        "grid": list(ex.grid),
        "steps": cinfo["steps"],
        "checkpoints": cinfo["checkpoints"],
    }
