"""Unified model configuration covering all 10 assigned architectures.

One dataclass, one model implementation (models/model.py); families select
which sub-blocks are instantiated:

  dense   — pre-norm decoder: GQA/MLA attention + SwiGLU MLP
  moe     — dense attention + top-k routed expert MLP
  ssm     — Mamba2 SSD blocks only (attention-free)
  hybrid  — Mamba2 backbone + a weight-shared attention block every k layers
  vlm     — dense decoder + cross-attention layers every k layers (image
            patch embeddings arrive precomputed: the frontend is a stub)
  audio   — encoder-only (bidirectional) transformer over precomputed frame
            embeddings (frontend stub); masked-prediction head
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "FAMILIES"]

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attention: str = "gqa"  # 'gqa' | 'mla' | 'none'
    causal: bool = True

    # MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style shared attention block)
    hybrid_attn_every: int = 0

    # vlm (llama-3.2-vision-style cross attention)
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # audio / vlm stub frontend embedding width
    d_frontend: int = 0

    # parallelism profile: 'auto' (heads-divisibility heuristic), 'tp', 'dp'
    parallelism: str = "auto"
    # attention implementation: 'xla' (einsum+softmax; what the dry-run
    # lowers) or 'flash' (Pallas online-softmax kernel; TPU runtime path —
    # the dry-run costs it via the kernel-adjusted roofline, §Perf)
    attention_impl: str = "xla"
    # ZeRO-3 parameter sharding over 'data' (default). False = params
    # replicated over 'data' (TP/EP-only storage) with ZeRO-1 moments —
    # removes per-layer weight all-gathers; right for models whose per-chip
    # TP/EP shard already fits (e.g. fine-grained MoE; §Perf cell B).
    zero3: bool = True

    # numerics / execution
    dtype: str = "bfloat16"
    # 'full' (recompute everything in bwd) is the default: at 16 GB/chip the
    # carry stack alone is the budget; 'dots' trades ~1/3 more HBM for fewer
    # recompute FLOPs and is a per-arch hillclimb lever (EXPERIMENTS.md §Perf).
    remat: str = "full"  # 'none' | 'dots' | 'full'
    # attention chunking for long sequences (memory-efficient online softmax)
    attn_chunk: int = 1024
    long_context_threshold: int = 8192

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family in ("ssm", "hybrid") and self.ssm_state <= 0:
            raise ValueError(f"{self.family} requires ssm_state > 0")
        if self.family == "moe" and self.n_experts <= 0:
            raise ValueError("moe requires n_experts > 0")
        if self.attention == "mla" and self.kv_lora_rank <= 0:
            raise ValueError("mla requires kv_lora_rank > 0")

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to the model-axis size so the embedding/lm_head
        always shard on the vocab dim (pad logits are masked in the loss and
        sampling paths). 50280->50288, 73448->73456, 504->512."""
        from repro.distributed.constants import MODEL_AXIS_SIZE

        m = MODEL_AXIS_SIZE
        return ((self.vocab + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.family not in ("ssm",)

    @property
    def is_decoder(self) -> bool:
        return self.family != "audio"

    def param_count(self) -> int:
        """Analytical parameter count (exact for our construction)."""
        from repro.models.model import count_params_analytical

        return count_params_analytical(self)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed experts count)."""
        from repro.models.model import count_params_analytical

        return count_params_analytical(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        """Derived config (used for reduced smoke-test instantiations)."""
        return dataclasses.replace(self, **overrides)
