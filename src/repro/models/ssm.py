"""Mamba2 / SSD (state-space duality) block — chunked dual-form scan.

Recurrence (per head h, state N, head channels P):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T        y_t = C_t h_t + D x_t

The chunked dual form (arXiv:2405.21060) splits the sequence into chunks of Q
tokens: within a chunk the contribution is an attention-like quadratic einsum
(MXU-friendly); across chunks only the [H, N, P] states flow through a
lax.scan. This is the TPU-idiomatic realization: the quadratic intra-chunk
term feeds the MXU, the inter-chunk scan is O(L/Q) sequential steps.

Projections are kept separate (z/x/B/C/dt) rather than fused so each output
dim gets a clean tensor-parallel sharding (heads on 'model'; B/C are
group-shared and replicated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

__all__ = ["ssm_schema", "ssm_forward", "ssm_decode", "ssm_state_shapes"]


def ssm_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    w = cfg.ssm_conv_width
    return {
        "in_z": ParamDef((d, di), "normal", ("fsdp", "tp")),
        "in_x": ParamDef((d, di), "normal", ("fsdp", "tp")),
        "in_b": ParamDef((d, gn), "normal", ("fsdp", None)),
        "in_c": ParamDef((d, gn), "normal", ("fsdp", None)),
        "in_dt": ParamDef((d, h), "normal", ("fsdp", "tp")),
        "conv_x": ParamDef((w, di), "normal", (None, "tp")),
        "conv_b": ParamDef((w, gn), "normal", (None, None)),
        "conv_c": ParamDef((w, gn), "normal", (None, None)),
        "a_log": ParamDef((h,), "a_log", ("tp",)),
        "d_skip": ParamDef((h,), "ones", ("tp",)),
        "dt_bias": ParamDef((h,), "dt_bias", ("tp",)),
        "gate_norm": ParamDef((di,), "ones", ("tp",)),
        "out": ParamDef((di, d), "scaled", ("tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: [B, L, C], w: [W, C]. Returns (y, new_state).

    ``state`` is the last W-1 inputs from the previous segment ([B, W-1, C]).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, -(width - 1) :, :] if width > 1 else state
    return jax.nn.silu(y), new_state


def _project(p: dict, u: jax.Array, cfg: ModelConfig):
    """Shared by prefill/decode: projections + activation shaping."""
    u = constrain(u, "dp", None, None)  # SP gather at projection entry
    b, l, _ = u.shape
    h, hp = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    z = u @ p["in_z"]
    x = u @ p["in_x"]
    bb = u @ p["in_b"]
    cc = u @ p["in_c"]
    dt = jax.nn.softplus(
        (u @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B, L, H]
    return z, x, bb.reshape(b, l, g, n), cc.reshape(b, l, g, n), dt, (h, hp, g, n)


def ssd_chunked(
    x: jax.Array,  # [B, L, H, P]  (dt folded in by caller? no — passed raw)
    dt: jax.Array,  # [B, L, H] (post-softplus, f32)
    a: jax.Array,  # [H] negative, f32
    b_mat: jax.Array,  # [B, L, H, N] (already broadcast from groups)
    c_mat: jax.Array,  # [B, L, H, N]
    chunk: int,
    init_state: jax.Array | None = None,  # [B, H, N, P]
):
    """Chunked SSD. Returns (y [B, L, H, P], final_state [B, H, N, P])."""
    bsz, l_orig, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l_orig)
    pad = (-l_orig) % q
    if pad:
        # Zero-pad the tail: dt=0 makes padded steps exact no-ops (decay=1,
        # no state update); the padded outputs are sliced away below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_orig + pad
    nc = l // q

    xf = x.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_mat.astype(jnp.float32).reshape(bsz, nc, q, h, n)
    cc = c_mat.astype(jnp.float32).reshape(bsz, nc, q, h, n)

    da = dtc * a[None, None, None, :]  # [B, nc, q, H], negative
    cs = jnp.cumsum(da, axis=2)  # inclusive
    # Intra-chunk quadratic term: seg[b,c,h,i,j] = exp(cs_i - cs_j), i >= j.
    cb = jnp.einsum("bcihn,bcjhn->bchij", cc, bc)
    cs_i = cs.transpose(0, 1, 3, 2)  # [B, nc, H, q]
    seg = jnp.exp(cs_i[..., :, None] - cs_i[..., None, :])  # [B,nc,H,i,j]
    mask = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.where(mask, cb * seg, 0.0) * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xf)

    # Per-chunk outgoing state: decay_to_end[b,c,h,j] = exp(cs_last - cs_j).
    decay_to_end = jnp.exp(cs_i[..., -1:] - cs_i)  # [B, nc, H, q]
    wgt = dtc * decay_to_end.transpose(0, 1, 3, 2)  # [B, nc, q, H]
    s_chunk = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", bc, wgt, xf)
    chunk_decay = jnp.exp(cs_i[..., -1])  # [B, nc, H]

    h0 = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(carry, inp):
        dec, s_c = inp  # [B, H], [B, H, N, P]
        new = dec[..., None, None] * carry + s_c
        return new, carry  # emit state *entering* the chunk

    final, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0))
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # [B, nc, H, N, P]
    y_inter = jnp.einsum(
        "bcihn,bcih,bchnp->bcihp", cc, jnp.exp(cs), h_prev
    )
    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :l_orig]
    return y, final


def ssm_forward(
    p: dict,
    u: jax.Array,  # [B, L, D]
    cfg: ModelConfig,
    state: dict | None = None,
):
    """Full-sequence Mamba2 block. Returns (out [B, L, D], new_state)."""
    bsz, l, d = u.shape
    z, x, bb, cc, dt, (h, hp, g, n) = _project(p, u, cfg)
    conv_state_x = state["conv_x"] if state else None
    conv_state_b = state["conv_b"] if state else None
    conv_state_c = state["conv_c"] if state else None
    x, ncx = _causal_conv(x, p["conv_x"], conv_state_x)
    bb2, ncb = _causal_conv(bb.reshape(bsz, l, -1), p["conv_b"], conv_state_b)
    cc2, ncc = _causal_conv(cc.reshape(bsz, l, -1), p["conv_c"], conv_state_c)
    bb = bb2.reshape(bsz, l, g, n)
    cc = cc2.reshape(bsz, l, g, n)
    rep = h // g
    b_h = jnp.repeat(bb, rep, axis=2)  # [B, L, H, N]
    c_h = jnp.repeat(cc, rep, axis=2)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = constrain(x.reshape(bsz, l, h, hp), "dp", None, "tp", None)
    b_h = constrain(b_h, "dp", None, "tp", None)
    c_h = constrain(c_h, "dp", None, "tp", None)
    dt = constrain(dt, "dp", None, "tp")
    ssm_state = state["ssm"] if state else None
    y, final = ssd_chunked(xh, dt, a, b_h, c_h, cfg.ssm_chunk, ssm_state)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.astype(u.dtype).reshape(bsz, l, h * hp)
    # Gated RMSNorm (mamba2 norm-before-out with z gate).
    from repro.models.layers import rmsnorm

    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    new_state = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "ssm": final}
    return constrain(y @ p["out"], "dp", "sp", None), new_state


def ssm_decode(p: dict, u: jax.Array, cfg: ModelConfig, state: dict):
    """Single-token recurrent step. u: [B, 1, D]; state from ssm_state_shapes."""
    bsz = u.shape[0]
    z, x, bb, cc, dt, (h, hp, g, n) = _project(p, u, cfg)
    x, ncx = _causal_conv(x, p["conv_x"], state["conv_x"])
    bb2, ncb = _causal_conv(bb.reshape(bsz, 1, -1), p["conv_b"], state["conv_b"])
    cc2, ncc = _causal_conv(cc.reshape(bsz, 1, -1), p["conv_c"], state["conv_c"])
    rep = h // g
    b_h = jnp.repeat(bb2.reshape(bsz, 1, g, n), rep, axis=2)[:, 0]  # [B, H, N]
    c_h = jnp.repeat(cc2.reshape(bsz, 1, g, n), rep, axis=2)[:, 0]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt0 = dt[:, 0]  # [B, H]
    xh = x.reshape(bsz, h, hp).astype(jnp.float32)
    hstate = state["ssm"]  # [B, H, N, P] f32
    decay = jnp.exp(dt0 * a[None, :])  # [B, H]
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt0, b_h.astype(jnp.float32), xh)
    hnew = decay[..., None, None] * hstate + upd
    y = jnp.einsum("bhn,bhnp->bhp", c_h.astype(jnp.float32), hnew)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, h * hp).astype(u.dtype)
    from repro.models.layers import rmsnorm

    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    new_state = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "ssm": hnew}
    return y @ p["out"], new_state


def ssm_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    """Zero-init decode state for one layer."""
    w = cfg.ssm_conv_width
    gn = cfg.ssm_groups * cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_inner), jnp.bfloat16),
        "conv_b": jnp.zeros((batch, w - 1, gn), jnp.bfloat16),
        "conv_c": jnp.zeros((batch, w - 1, gn), jnp.bfloat16),
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        ),
    }
