"""Unified model: schema, init, train forward, prefill, decode — all families.

Layers are scanned (stacked params, one lowered layer body) so HLO size and
compile time stay bounded at 100-layer scale; heterogeneous structures use:

  * hybrid — lax.cond inside the scan applies the weight-shared attention
    block every ``hybrid_attn_every`` layers (zamba2)
  * vlm    — grouped scan: (cross_attn_every - 1) self layers scanned inside
    each group, then one gated cross-attention layer (llama-3.2-vision)

Caches are stacked on the layer (or application/group) dimension and scanned
together with the layer params during decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models.params import ParamDef, init_params, param_specs, stack_schema

__all__ = [
    "model_schema",
    "init_model",
    "model_param_specs",
    "forward_train",
    "loss_fn",
    "forward_prefill",
    "decode_step",
    "init_cache",
    "count_params_analytical",
]


# ------------------------------------------------------------------- schema


def _layer_schema(cfg: ModelConfig) -> dict:
    """One stackable decoder/encoder layer."""
    s: dict[str, Any] = {}
    if cfg.family == "ssm" or cfg.family == "hybrid":
        s["ln"] = L.norm_schema(cfg.d_model)
        s["ssm"] = SSM.ssm_schema(cfg)
        return s
    s["ln1"] = L.norm_schema(cfg.d_model)
    if cfg.attention == "mla":
        s["attn"] = L.mla_schema(cfg)
    else:
        s["attn"] = L.attn_schema(cfg)
    s["ln2"] = L.norm_schema(cfg.d_model)
    if cfg.family == "moe":
        s["moe"] = MOE.moe_schema(cfg)
    else:
        s["mlp"] = L.mlp_schema(cfg)
    return s


def _cross_layer_schema(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.norm_schema(cfg.d_model),
        "xattn": L.attn_schema(cfg, cross=True),
        "ln2": L.norm_schema(cfg.d_model),
        "mlp": L.mlp_schema(cfg),
    }


def _shared_block_schema(cfg: ModelConfig) -> dict:
    """zamba2's weight-shared attention+MLP block (applied at intervals)."""
    return {
        "ln1": L.norm_schema(cfg.d_model),
        "attn": L.attn_schema(cfg),
        "ln2": L.norm_schema(cfg.d_model),
        "mlp": L.mlp_schema(cfg),
    }


def vlm_counts(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, self_per_group, n_cross) for the grouped vlm scan."""
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    return n_groups, every - 1, n_groups


def hybrid_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, trailing) — zamba2: shared attn after every `every` mamba
    layers; `trailing` mamba layers close the stack without attention."""
    every = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // every
    return n_groups, cfg.n_layers - n_groups * every


def _hybrid_split(cfg: ModelConfig, stacked):
    """Reshape stacked [L, ...] layer params into ([G, every, ...], [T, ...])."""
    n_groups, trailing = hybrid_counts(cfg)
    every = cfg.hybrid_attn_every
    head = jax.tree.map(
        lambda x: x[: n_groups * every].reshape(n_groups, every, *x.shape[1:]),
        stacked,
    )
    tail = jax.tree.map(lambda x: x[n_groups * every :], stacked)
    return head, tail


def model_schema(cfg: ModelConfig) -> dict:
    s: dict[str, Any] = {}
    d, v = cfg.d_model, cfg.padded_vocab
    # The 'vocab' logical axis maps to 'model' in BOTH profiles: the
    # embedding/lm_head are the dominant matrices of small archs and their
    # weight-grad einsums need the vocab dim sharded (otherwise GSPMD
    # gathers the full-batch logits cotangent — measured 13 GB/device).
    if cfg.family == "audio":
        s["frontend"] = ParamDef((cfg.d_frontend, d), "normal", ("fsdp", "tp"))
    else:
        s["tok_embed"] = ParamDef((v, d), "embed", ("vocab", "fsdp"))
    if cfg.family == "vlm":
        s["img_proj"] = ParamDef((cfg.d_frontend, d), "normal", ("fsdp", "tp"))
        n_groups, self_per, n_cross = vlm_counts(cfg)
        s["layers"] = stack_schema(
            stack_schema(_layer_schema(cfg), self_per), n_groups
        )
        s["cross_layers"] = stack_schema(_cross_layer_schema(cfg), n_groups)
    else:
        s["layers"] = stack_schema(_layer_schema(cfg), cfg.n_layers)
    if cfg.family == "hybrid":
        s["shared"] = _shared_block_schema(cfg)
    s["final_norm"] = L.norm_schema(d)
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamDef((d, v), "normal", ("fsdp", "vocab"))
    return s


def init_model(key: jax.Array, cfg: ModelConfig):
    return init_params(key, model_schema(cfg), getattr(jnp, cfg.dtype))


def model_param_specs(cfg: ModelConfig):
    return param_specs(model_schema(cfg))


def count_params_analytical(cfg: ModelConfig, active_only: bool = False) -> int:
    import numpy as np

    schema = model_schema(cfg)
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, ParamDef))
    total = sum(int(np.prod(d.shape)) for d in leaves)
    if active_only and cfg.family == "moe":
        expert_leaves = jax.tree.leaves(
            {"g": MOE.moe_schema(cfg)}, is_leaf=lambda x: isinstance(x, ParamDef)
        )
        per_layer_experts = sum(
            int(np.prod(d.shape)) for d in expert_leaves if len(d.shape) == 3
        )
        inactive = (
            per_layer_experts
            * cfg.n_layers
            * (cfg.n_experts - cfg.experts_per_token)
            // cfg.n_experts
        )
        total -= inactive
    return total


# ----------------------------------------------------------- layer execution


def _dense_layer(lp, x, positions, cfg: ModelConfig, aux_acc):
    # Sequence-parallel residual stream: the scan carry (== the saved
    # backprop residual) stays seq-sharded over 'model' between layers.
    x = constrain(x, "dp", "sp", None)
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, _ = L.mla_forward(lp["attn"], h, positions, cfg)
    else:
        a, _ = L.attn_forward(lp["attn"], h, positions, cfg)
    x = x + a
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = MOE.moe_forward(lp["moe"], h, cfg)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()}
    else:
        m = L.mlp_forward(lp["mlp"], h)
    return x + m, aux_acc


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _mask_pad_logits(logits, cfg: ModelConfig):
    """padded_vocab > vocab: pad columns get -inf (softmax/argmax-neutral)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    idx = jnp.arange(cfg.padded_vocab)
    return jnp.where(idx < cfg.vocab, logits, -1e30)


# ------------------------------------------------------------- train forward


def forward_train(params, batch: dict, cfg: ModelConfig):
    """Full training forward: returns (logits [B,S,V], aux metrics dict).

    batch keys: 'tokens' (decoder) | 'frames' (audio); 'image_embeds' (vlm).
    """
    if cfg.family == "audio":
        x = batch["frames"].astype(getattr(jnp, cfg.dtype)) @ params["frontend"]
        bsz, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = jnp.take(params["tok_embed"], tokens, axis=0)
    # Anchor the batch/seq layout right at the entry: the embedding gather
    # would otherwise propagate the table's ZeRO sharding onto d_model and
    # let GSPMD gather the batch instead (fatal for the pure-DP profile).
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    aux0 = {}

    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(x.dtype) @ params["img_proj"]

        def group_body(carry, gp):
            x, aux = carry
            self_lps, cross_lp = gp

            def inner(carry2, lp):
                x2, aux2 = carry2
                x2, aux2 = _dense_layer(lp, x2, positions, cfg, aux2)
                return (x2, aux2), None

            # Nested remat: the group bwd re-runs this inner scan, which
            # itself only keeps per-layer carries.
            (x, aux), _ = jax.lax.scan(_remat(inner, cfg), (x, aux), self_lps)
            h = L.rmsnorm(x, cross_lp["ln1"], cfg.norm_eps)
            a, _ = L.attn_forward(cross_lp["xattn"], h, positions, cfg, kv_x=img)
            x = x + a
            h = L.rmsnorm(x, cross_lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_forward(cross_lp["mlp"], h)
            return (x, aux), None

        # Remat at GROUP granularity: only the 20 group carries are saved;
        # the 4 self layers + cross layer recompute in bwd.
        group_r = _remat(group_body, cfg)
        (x, aux), _ = jax.lax.scan(
            group_r, (x, aux0), (params["layers"], params["cross_layers"])
        )
    elif cfg.family in ("ssm", "hybrid"):

        def mamba_body(carry, lp):
            x, aux = carry
            x = constrain(x, "dp", "sp", None)
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            o, _ = SSM.ssm_forward(lp["ssm"], h, cfg)
            return (x + o, aux), None

        mamba_r = _remat(mamba_body, cfg)
        if cfg.family == "ssm":
            (x, aux), _ = jax.lax.scan(mamba_r, (x, aux0), params["layers"])
        else:
            head, tail = _hybrid_split(cfg, params["layers"])
            sp = params["shared"]

            def shared_block(x):
                h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
                a, kv = L.attn_forward(sp["attn"], h, positions, cfg)
                x = x + a
                h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
                return x + L.mlp_forward(sp["mlp"], h), kv

            shared_r = _remat(shared_block, cfg)

            def group_body(carry, group_lps):
                x, aux = carry
                (x, aux), _ = jax.lax.scan(mamba_r, (x, aux), group_lps)
                x, _ = shared_r(x)
                return (x, aux), None

            (x, aux), _ = jax.lax.scan(group_body, (x, aux0), head)
            (x, aux), _ = jax.lax.scan(mamba_r, (x, aux), tail)
    else:  # dense / moe / audio

        def body(carry, lp):
            x, aux = carry
            x, aux = _dense_layer(lp, x, positions, cfg, aux)
            return (x, aux), None

        body_r = _remat(body, cfg)
        # MoE aux metrics must exist in the carry with stable structure.
        if cfg.family == "moe":
            aux0 = {
                "moe_balance_loss": jnp.float32(0.0),
                "moe_z_loss": jnp.float32(0.0),
                "moe_dropped_frac": jnp.float32(0.0),
            }
        (x, aux), _ = jax.lax.scan(body_r, (x, aux0), params["layers"])

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["tok_embed"])
    else:
        logits = x @ params["lm_head"]
    if cfg.family == "moe":
        aux = {k: v / cfg.n_layers for k, v in aux.items()}
    return _mask_pad_logits(logits.astype(jnp.float32), cfg), aux


def loss_fn(params, batch: dict, cfg: ModelConfig, logits_spec_constraint=None):
    """Cross-entropy loss (+ MoE aux). Decoder: next-token; audio: masked pred."""
    logits, aux = forward_train(params, batch, cfg)
    if logits_spec_constraint is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_spec_constraint)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if cfg.family == "audio":
        mask = batch["mask"].astype(jnp.float32)
        loss = (ce * mask).sum() / jnp.clip(mask.sum(), 1.0)
    else:
        loss = ce.mean()
    metrics = {"ce_loss": loss, **aux}
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_coef * aux["moe_balance_loss"]
        loss = loss + 1e-4 * aux["moe_z_loss"]
    return loss, metrics


# -------------------------------------------------------------- KV/SSM cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked decode cache for the whole model. dtype bf16 (f32 ssm states)."""
    hd = cfg.resolved_head_dim
    kvh = cfg.n_kv_heads
    if cfg.family == "audio":
        return {}  # encoder-only: no decode state
    if cfg.family in ("ssm", "hybrid"):
        one = SSM.ssm_state_shapes(cfg, batch)
        states = jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers, *x.shape), x.dtype), one
        )
        cache = {"ssm": states}
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            n_apps = cfg.n_layers // cfg.hybrid_attn_every
            cache["shared_k"] = jnp.zeros(
                (n_apps, batch, max_seq, kvh, hd), jnp.bfloat16
            )
            cache["shared_v"] = jnp.zeros(
                (n_apps, batch, max_seq, kvh, hd), jnp.bfloat16
            )
        return cache
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros(
                (cfg.n_layers, batch, max_seq, cfg.kv_lora_rank), jnp.bfloat16
            ),
            "krope": jnp.zeros(
                (cfg.n_layers, batch, max_seq, cfg.qk_rope_dim), jnp.bfloat16
            ),
        }
    if cfg.family == "vlm":
        n_groups, self_per, n_cross = vlm_counts(cfg)
        return {
            "k": jnp.zeros((n_groups, self_per, batch, max_seq, kvh, hd), jnp.bfloat16),
            "v": jnp.zeros((n_groups, self_per, batch, max_seq, kvh, hd), jnp.bfloat16),
            "xk": jnp.zeros((n_groups, batch, cfg.n_image_tokens, kvh, hd), jnp.bfloat16),
            "xv": jnp.zeros((n_groups, batch, cfg.n_image_tokens, kvh, hd), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, kvh, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, kvh, hd), jnp.bfloat16),
    }


# ------------------------------------------------------------------- decode


def _embed_tokens(params, tokens):
    return jnp.take(params["tok_embed"], tokens, axis=0)


def _row(stacked, i):
    return jax.lax.dynamic_index_in_dim(stacked, i, 0, keepdims=False)


def _put(stacked, row, i):
    return jax.lax.dynamic_update_index_in_dim(stacked, row, i, 0)


def decode_step(params, cache: dict, token: jax.Array, pos: jax.Array, cfg: ModelConfig,
                image_embeds: jax.Array | None = None):
    """One decode step. token: [B, 1] int32; pos: scalar int32.

    Returns (logits [B, vocab] f32, new_cache). VLM cross K/V must be
    prefilled (forward_prefill); image_embeds is accepted for API symmetry.

    Memory discipline: big caches travel in the scan CARRY and are updated
    with dynamic_update_index on the (unsharded) layer dim — XLA performs
    these in place on the donated buffer. Passing caches as scan xs/ys
    instead costs ~3x the cache in live buffers (measured; see §Perf).
    """
    x = _embed_tokens(params, token)
    bsz = x.shape[0]

    if cfg.family in ("ssm", "hybrid"):

        def mamba_body(carry, inp):
            x, = carry
            lp, st = inp
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            o, new_st = SSM.ssm_decode(lp["ssm"], h, cfg, st)
            return (x + o,), new_st

        if cfg.family == "ssm":
            (x,), new_states = jax.lax.scan(
                mamba_body, (x,), (params["layers"], cache["ssm"])
            )
            new_cache = {"ssm": new_states}
        else:
            n_groups, trailing = hybrid_counts(cfg)
            every = cfg.hybrid_attn_every
            head, tail = _hybrid_split(cfg, params["layers"])
            st_head, st_tail = _hybrid_split(cfg, cache["ssm"])
            sp = params["shared"]

            def group_body(carry, inp):
                x, kc, vc = carry
                group_lps, group_sts, gi = inp
                (x,), new_sts = jax.lax.scan(mamba_body, (x,), (group_lps, group_sts))
                h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
                a, nk, nv = L.attn_decode(
                    sp["attn"], h, pos, _row(kc, gi), _row(vc, gi), cfg
                )
                x = x + a
                h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
                x = x + L.mlp_forward(sp["mlp"], h)
                return (x, _put(kc, nk, gi), _put(vc, nv, gi)), new_sts

            (x, nks, nvs), head_sts = jax.lax.scan(
                group_body,
                (x, cache["shared_k"], cache["shared_v"]),
                (head, st_head, jnp.arange(n_groups)),
            )
            (x,), tail_sts = jax.lax.scan(mamba_body, (x,), (tail, st_tail))
            new_states = jax.tree.map(
                lambda h, t: jnp.concatenate(
                    [h.reshape(n_groups * every, *h.shape[2:]), t], axis=0
                ),
                head_sts,
                tail_sts,
            )
            new_cache = {"ssm": new_states, "shared_k": nks, "shared_v": nvs}
    elif cfg.family == "vlm":
        # Cross K/V are static during decode and must be prefilled into the
        # cache (forward_prefill); image_embeds is accepted for API symmetry.
        n_groups, self_per, _ = vlm_counts(cfg)
        positions = jnp.full((bsz, 1), pos, jnp.int32)

        def group_body(carry, gp):
            x, kc, vc = carry
            self_lps, cross_lp, xk, xv, gi = gp
            kg, vg = _row(kc, gi), _row(vc, gi)  # [sp, B, S, K, hd]

            def inner(carry2, inp2):
                x2, kg, vg = carry2
                lp, li = inp2
                h = L.rmsnorm(x2, lp["ln1"], cfg.norm_eps)
                a, nk, nv = L.attn_decode(
                    lp["attn"], h, pos, _row(kg, li), _row(vg, li), cfg
                )
                x2 = x2 + a + _post_mlp(lp, x2 + a, cfg)
                return (x2, _put(kg, nk, li), _put(vg, nv, li)), None

            (x, kg, vg), _ = jax.lax.scan(
                inner, (x, kg, vg), (self_lps, jnp.arange(self_per))
            )
            h = L.rmsnorm(x, cross_lp["ln1"], cfg.norm_eps)
            # Cross K/V are static during decode; use cached values.
            q, _, _ = L._project_qkv(cross_lp["xattn"], h, h, cfg)
            kx = xk.astype(q.dtype)
            vx = xv.astype(q.dtype)
            npos = jnp.zeros((bsz, kx.shape[1]), jnp.int32)
            o = L.attention_op(q, kx, vx, positions, npos, False)
            o = o.reshape(bsz, 1, -1) @ cross_lp["xattn"]["wo"]
            gate = jnp.tanh(cross_lp["xattn"]["gate"].astype(jnp.float32)).astype(o.dtype)
            x = x + gate * o
            h = L.rmsnorm(x, cross_lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_forward(cross_lp["mlp"], h)
            return (x, _put(kc, kg, gi), _put(vc, vg, gi)), None

        (x, nks, nvs), _ = jax.lax.scan(
            group_body,
            (x, cache["k"], cache["v"]),
            (
                params["layers"],
                params["cross_layers"],
                cache["xk"],
                cache["xv"],
                jnp.arange(n_groups),
            ),
        )
        new_cache = {"k": nks, "v": nvs, "xk": cache["xk"], "xv": cache["xv"]}
    else:  # dense / moe
        if cfg.attention == "mla":
            def body(carry, inp):
                x, cc, krc = carry
                lp, li = inp
                h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nckv, nkr = L.mla_decode(
                    lp["attn"], h, pos, _row(cc, li), _row(krc, li), cfg
                )
                x = x + a
                x = x + _post_mlp(lp, x, cfg)
                return (x, _put(cc, nckv, li), _put(krc, nkr, li)), None

            (x, nckv, nkr), _ = jax.lax.scan(
                body,
                (x, cache["ckv"], cache["krope"]),
                (params["layers"], jnp.arange(cfg.n_layers)),
            )
            new_cache = {"ckv": nckv, "krope": nkr}
        else:
            def body(carry, inp):
                x, kc, vc = carry
                lp, li = inp
                h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, nk, nv = L.attn_decode(
                    lp["attn"], h, pos, _row(kc, li), _row(vc, li), cfg
                )
                x = x + a
                x = x + _post_mlp(lp, x, cfg)
                return (x, _put(kc, nk, li), _put(vc, nv, li)), None

            (x, nk, nv), _ = jax.lax.scan(
                body,
                (x, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.n_layers)),
            )
            new_cache = {"k": nk, "v": nv}

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["tok_embed"])
    else:
        logits = x @ params["lm_head"]
    return _mask_pad_logits(logits[:, 0].astype(jnp.float32), cfg), new_cache


def _post_mlp(lp, x, cfg: ModelConfig):
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        # Decode (S==1): one group per token — keeps the batch dim sharded and
        # is provably drop-free. Longer sequences use the train grouping so
        # prefill routing (and drops) match forward_train exactly.
        group = 1 if x.shape[1] == 1 else min(1024, x.shape[0] * x.shape[1])
        m, _ = MOE.moe_forward(lp["moe"], h, cfg, group_size=group)
        return m
    return L.mlp_forward(lp["mlp"], h)


# ------------------------------------------------------------------ prefill


def forward_prefill(params, batch: dict, cache: dict, cfg: ModelConfig):
    """Prefill: full forward that also populates the decode cache.

    Returns (last-position logits [B, vocab], cache). Implemented as the
    train forward plus cache writes; decode shapes lower `decode_step`, this
    lowers for the `prefill_*` input shapes.
    """
    if cfg.family == "audio":
        # Encoder-only: "prefill" is a plain full forward (no decode cache).
        logits_full, _ = forward_train(params, batch, cfg)
        return logits_full[:, -1].astype(jnp.float32), {}
    tokens = batch["tokens"] if "tokens" in batch else None
    x = _embed_tokens(params, tokens)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))

    if cfg.family in ("ssm", "hybrid"):
        smax_attn = cache["shared_k"].shape[2] if "shared_k" in cache else 0

        def pad_seq(arr, size):
            pad = size - arr.shape[1]
            return jnp.pad(arr, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad > 0 else arr

        def mamba_body(carry, lp):
            x, = carry
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            o, new_st = SSM.ssm_forward(lp["ssm"], h, cfg, state=None)
            return (x + o,), new_st

        mamba_r = _remat(mamba_body, cfg)
        if cfg.family == "ssm":
            (x,), new_states = jax.lax.scan(mamba_r, (x,), params["layers"])
            new_cache = {"ssm": new_states}
        else:
            n_groups, trailing = hybrid_counts(cfg)
            every = cfg.hybrid_attn_every
            head, tail = _hybrid_split(cfg, params["layers"])
            sp = params["shared"]

            def group_body(carry, group_lps):
                x, = carry
                (x,), new_sts = jax.lax.scan(mamba_r, (x,), group_lps)
                h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
                a, (k, v) = L.attn_forward(sp["attn"], h, positions, cfg)
                x = x + a
                h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
                x = x + L.mlp_forward(sp["mlp"], h)
                return (x,), (
                    new_sts,
                    pad_seq(k.astype(jnp.bfloat16), smax_attn),
                    pad_seq(v.astype(jnp.bfloat16), smax_attn),
                )

            (x,), (head_sts, ks, vs) = jax.lax.scan(group_body, (x,), head)
            (x,), tail_sts = jax.lax.scan(mamba_r, (x,), tail)
            new_states = jax.tree.map(
                lambda h_, t_: jnp.concatenate(
                    [h_.reshape(n_groups * every, *h_.shape[2:]), t_], axis=0
                ),
                head_sts,
                tail_sts,
            )
            new_cache = {"ssm": new_states, "shared_k": ks, "shared_v": vs}
    else:
        # Attention families: one pass that both fills the caches and yields
        # the final residual stream; only last-position logits materialize
        # (a full [B, S, V] f32 logits tensor would be GBs at 32k prefill).
        x, new_cache = _fill_attention_cache(params, batch, cache, cfg)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["tok_embed"])
    else:
        logits = x[:, -1:] @ params["lm_head"]
    return _mask_pad_logits(logits[:, 0].astype(jnp.float32), cfg), new_cache


def _fill_attention_cache(params, batch, cache, cfg: ModelConfig):
    """Populate KV caches by scanning layers once (projection-only pass).

    NOTE: this recomputes the residual stream (cheap relative to decode use);
    exactness is asserted in tests (decode == teacher forcing).
    """
    tokens = batch.get("tokens")
    x = _embed_tokens(params, tokens)
    bsz, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    smax = (cache["k"].shape[-3] if "k" in cache else cache["ckv"].shape[-2])

    def pad_to(arr, size, axis):
        pad = size - arr.shape[axis]
        if pad <= 0:
            return arr
        widths = [(0, 0)] * arr.ndim
        widths[axis] = (0, pad)
        return jnp.pad(arr, widths)

    def to_cache_layout(kv):  # [B, S, K, hd] -> cache sharding (seq on model)
        return constrain(kv, "dp", "tp", None, None)

    if cfg.family == "vlm":
        img = batch["image_embeds"].astype(x.dtype) @ params["img_proj"]

        def group_body(carry, gp):
            x, = carry
            self_lps, cross_lp = gp

            def inner(carry2, lp):
                x2, = carry2
                h = L.rmsnorm(x2, lp["ln1"], cfg.norm_eps)
                a, (k, v) = L.attn_forward(lp["attn"], h, positions, cfg)
                x2 = x2 + a
                x2 = x2 + _post_mlp(lp, x2, cfg)
                return (x2,), (
                    to_cache_layout(pad_to(k.astype(jnp.bfloat16), smax, 1)),
                    to_cache_layout(pad_to(v.astype(jnp.bfloat16), smax, 1)),
                )

            (x,), (ks, vs) = jax.lax.scan(inner, (x,), self_lps)
            h = L.rmsnorm(x, cross_lp["ln1"], cfg.norm_eps)
            a, (xk, xv) = L.attn_forward(cross_lp["xattn"], h, positions, cfg, kv_x=img)
            x = x + a
            h = L.rmsnorm(x, cross_lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_forward(cross_lp["mlp"], h)
            return (x,), (ks, vs, xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16))

        (x,), (ks, vs, xks, xvs) = jax.lax.scan(
            group_body, (x,), (params["layers"], params["cross_layers"])
        )
        return x, {"k": ks, "v": vs, "xk": xks, "xv": xvs}

    if cfg.attention == "mla":
        def body(carry, lp):
            x2, = carry
            h = L.rmsnorm(x2, lp["ln1"], cfg.norm_eps)
            a, (ckv, krope) = L.mla_forward(lp["attn"], h, positions, cfg)
            x2 = x2 + a
            x2 = x2 + _post_mlp(lp, x2, cfg)
            return (x2,), (
                constrain(pad_to(ckv.astype(jnp.bfloat16), smax, 1), "dp", "tp", None),
                constrain(pad_to(krope.astype(jnp.bfloat16), smax, 1), "dp", "tp", None),
            )

        (x,), (ckvs, kropes) = jax.lax.scan(body, (x,), params["layers"])
        return x, {"ckv": ckvs, "krope": kropes}

    def body(carry, lp):
        x2, = carry
        h = L.rmsnorm(x2, lp["ln1"], cfg.norm_eps)
        a, (k, v) = L.attn_forward(lp["attn"], h, positions, cfg)
        x2 = x2 + a
        x2 = x2 + _post_mlp(lp, x2, cfg)
        return (x2,), (
            to_cache_layout(pad_to(k.astype(jnp.bfloat16), smax, 1)),
            to_cache_layout(pad_to(v.astype(jnp.bfloat16), smax, 1)),
        )

    (x,), (ks, vs) = jax.lax.scan(body, (x,), params["layers"])
    return x, {"k": ks, "v": vs}
