"""Schema-driven parameter system.

A module's parameters are declared once as a nested dict of ``ParamDef``
(shape, init kind, logical partition axes). From one schema we derive:

  * ``init_params``  — materialized jnp arrays (PRNG-split per leaf path)
  * ``param_specs``  — the matching ``PartitionSpec`` tree for pjit
  * ``stack_schema`` — the scan-over-layers form ([L, ...] leaves)

Keeping init and sharding in one definition makes structural drift between
params and specs impossible (tests assert tree equality anyway).

Logical axis names -> mesh axes (see distributed/lm_sharding.py):
  'fsdp'  -> 'data'   (ZeRO-3 style parameter/optimizer sharding)
  'tp'    -> 'model'  (tensor parallel)
  None    -> replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["ParamDef", "init_params", "param_specs", "stack_schema", "tree_bytes"]

Schema = dict[str, Any]  # nested dicts with ParamDef leaves


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    init: str = "normal"  # normal|zeros|ones|scaled|embed|a_log|dt_bias
    axes: tuple[str | None, ...] = ()  # logical partition per dim
    scale: float = 0.02  # stddev for normal-family inits

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init in ("normal", "scaled", "embed"):
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)
    if d.init == "a_log":
        # Mamba2: A ~ -exp(A_log), A_log init log(U[1, 16]).
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(jnp.float32)  # keep f32 for stability
    if d.init == "dt_bias":
        # Inverse softplus of dt ~ U[1e-3, 1e-1].
        dt = jnp.exp(
            jax.random.uniform(key, d.shape, jnp.float32)
            * (np.log(0.1) - np.log(1e-3))
            + np.log(1e-3)
        )
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(key: jax.Array, schema: Schema, dtype=jnp.bfloat16):
    """Materialize a schema. PRNG folded by flattened leaf index (stable)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves, strict=True)]
    return jax.tree.unflatten(treedef, vals)


_LOGICAL_TO_MESH = {"fsdp": "data", "tp": "model", "vocab": "model", None: None}


def param_specs(schema: Schema, logical_to_mesh: dict | None = None):
    """PartitionSpec tree matching the schema structure."""
    table = _LOGICAL_TO_MESH if logical_to_mesh is None else logical_to_mesh

    def leaf(d: ParamDef):
        axes = d.axes if d.axes else (None,) * len(d.shape)
        return P(*[table.get(a, None) for a in axes])

    return jax.tree.map(leaf, schema, is_leaf=_is_def)


def stack_schema(schema: Schema, n: int) -> Schema:
    """Prepend a stacked-layer dim of size n to every leaf (scan form)."""

    def leaf(d: ParamDef):
        axes = d.axes if d.axes else (None,) * len(d.shape)
        return ParamDef((n, *d.shape), d.init, (None, *axes), d.scale)

    return jax.tree.map(leaf, schema, is_leaf=_is_def)


def tree_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
