"""Transformer primitives: RMSNorm, RoPE, GQA/MLA attention, SwiGLU.

All functions are pure; parameters arrive as dicts produced from the schemas
declared alongside each block (see models/params.py). Attention supports:

  * GQA with optional QKV bias (qwen-style), causal or bidirectional
  * chunked query processing with full-row softmax per chunk — the
    memory-efficient path for 32k+ prefill (peak scores = [*, chunk, S])
  * decode with an externally managed KV cache (positions passed in)
  * MLA (latent KV) in direct form for train/prefill and *absorbed* form for
    decode (scores in latent space; no per-step KV decompression)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

__all__ = [
    "rmsnorm",
    "rope",
    "attn_schema",
    "attn_forward",
    "attn_decode",
    "mla_schema",
    "mla_forward",
    "mla_decode",
    "mlp_schema",
    "mlp_forward",
    "norm_schema",
]

# ---------------------------------------------------------------- primitives


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_schema(dim: int) -> ParamDef:
    return ParamDef((dim,), "ones", (None,))


def _rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, S, H, hd]; positions: [B, S] (absolute)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------- scaled dot attn


def _sdpa(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Sk, K, hd]
    v: jax.Array,  # [B, Sk, K, vd]
    q_pos: jax.Array,  # [B, Sq]
    k_pos: jax.Array,  # [B, Sk]
    causal: bool,
    scale: float,
) -> jax.Array:
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    rep = h // kheads
    if rep != 1:
        # Materialize repeated KV so the scores einsum has a plain head dim:
        # with H % model_axis == 0 GSPMD shards scores on H with no
        # collectives inside attention (the repeat itself is sharded too).
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    scores = constrain(scores, "dp", "tp", None, None)
    if causal:
        mask = q_pos[:, None, :, None] >= k_pos[:, None, None, :]  # [B,1,Sq,Sk]
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshv->bqhv", w, v)
    return constrain(out.reshape(b, sq, h, v.shape[-1]), "dp", None, "tp", None)


def _sdpa_chunked(
    q, k, v, q_pos, k_pos, causal: bool, scale: float, chunk: int
) -> jax.Array:
    """Scan over query chunks — peak score memory [B, K, rep, chunk, Sk]."""
    b, sq, h, hd = q.shape
    n_chunks = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qs = q.reshape(b, n_chunks, chunk, h, hd)
    ps = q_pos.reshape(b, n_chunks, chunk)

    def body(_, inp):
        qc, pc = inp  # [B, chunk, H, hd], [B, chunk]
        return None, _sdpa(qc, k, v, pc, k_pos, causal, scale)

    _, out = jax.lax.scan(
        body, None, (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ps, 1, 0))
    )
    # out: [n_chunks, B, chunk, H, vd]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, v.shape[-1])
    return out


def attention_op(q, k, v, q_pos, k_pos, causal, chunk_threshold=8192, chunk=1024,
                 impl="xla"):
    scale = 1.0 / (q.shape[-1] ** 0.5)
    if impl == "flash":
        out = _flash(q, k, v, q_pos, k_pos, causal)
        if out is not None:
            return out
    with jax.named_scope("attn_core"):
        if q.shape[1] > chunk_threshold and q.shape[1] % chunk == 0:
            return _sdpa_chunked(q, k, v, q_pos, k_pos, causal, scale, chunk)
        return _sdpa(q, k, v, q_pos, k_pos, causal, scale)


def _flash(q, k, v, q_pos, k_pos, causal):
    """Pallas flash-attention path; None when shapes don't tile (caller
    falls back to the XLA path). Same-width heads only (GQA pre-repeated)."""
    from repro.kernels.common import on_cpu
    from repro.kernels.flash_attention import flash_attention_pallas

    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    if kh != h or v.shape[-1] != hd:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    bq = min(512, sq)
    bk = min(512, sk)
    if sq % bq or sk % bk:
        return None
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, v.shape[-1])
    qp = jnp.broadcast_to(q_pos[:, None, :], (b, h, sq)).reshape(b * h, sq)
    kp = jnp.broadcast_to(k_pos[:, None, :], (b, h, sk)).reshape(b * h, sk)
    out = flash_attention_pallas(
        qf, kf, vf, qp, kp, causal=causal, block_q=bq, block_k=bk,
        interpret=on_cpu(),
    )
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def cache_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write ``new`` [B, 1, ...] into ``cache`` [B, S, ...] at seq index pos.

    Formulated as a broadcast-select rather than dynamic_update_slice: a
    dynamic start index on the seq dim makes GSPMD unshard it (it cannot
    prove the write is shard-local), which at 32k context replicates the
    whole cache per layer. The select keeps the seq dim sharded; the cost is
    a full local-shard rewrite per step — the §Perf decode hillclimb
    replaces this with a shard_map-local DUS.
    """
    sel = jnp.arange(cache.shape[1], dtype=jnp.int32) == pos
    sel = sel.reshape((1, -1) + (1,) * (cache.ndim - 2))
    return jnp.where(sel, new.astype(cache.dtype), cache)


# ------------------------------------------------------------------ GQA attn


def attn_schema(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamDef((d, h * hd), "normal", ("fsdp", "tp")),
        "wk": ParamDef((d, k * hd), "normal", ("fsdp", "tp")),
        "wv": ParamDef((d, k * hd), "normal", ("fsdp", "tp")),
        "wo": ParamDef((h * hd, d), "scaled", ("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamDef((h * hd,), "zeros", ("tp",))
        s["bk"] = ParamDef((k * hd,), "zeros", ("tp",))
        s["bv"] = ParamDef((k * hd,), "zeros", ("tp",))
    if cross:
        # Tanh-gated cross attention (llama-3.2-vision style).
        s["gate"] = ParamDef((), "zeros", ())
    return s


def _project_qkv(p: dict, x: jax.Array, kv_x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    sk = kv_x.shape[1]
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    kk = kv_x @ p["wk"]
    vv = kv_x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        kk = kk + p["bk"]
        vv = vv + p["bv"]
    return (
        q.reshape(b, s, h, hd),
        kk.reshape(b, sk, k, hd),
        vv.reshape(b, sk, k, hd),
    )


def attn_forward(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    kv_x: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    causal: bool | None = None,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    ``kv_x`` switches to cross-attention (keys/values from another stream,
    e.g. image patch embeddings); cross attention is never causal.
    """
    cross = kv_x is not None
    # Megatron-SP: gather the seq-sharded residual stream once at the QKV
    # projection input (norms upstream ran seq-sharded).
    x = constrain(x, "dp", None, None)
    kv_src = kv_x if cross else x
    kv_pos = kv_positions if cross else positions
    q, k, v = _project_qkv(p, x, kv_src, cfg)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    is_causal = cfg.causal if causal is None else causal
    if cross:
        is_causal = False
        kv_pos = jnp.zeros(kv_src.shape[:2], jnp.int32)
    out = attention_op(
        q, k, v, positions, kv_pos, is_causal,
        chunk_threshold=cfg.long_context_threshold, chunk=cfg.attn_chunk,
        impl=cfg.attention_impl,
    )
    out = out.reshape(*x.shape[:2], -1) @ p["wo"]
    if cross:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return constrain(out, "dp", "sp", None), (k, v)


def attn_decode(
    p: dict,
    x: jax.Array,  # [B, 1, D]
    pos: jax.Array,  # [] scalar current position
    k_cache: jax.Array,  # [B, Smax, K, hd]  (seq dim sharded over 'model')
    v_cache: jax.Array,
    cfg: ModelConfig,
):
    """Single-token decode against a KV cache. Returns (out, new_k, new_v).

    Flash-decoding layout: the cache's *sequence* dim is sharded over the
    model axis; each shard scores its KV chunk and GSPMD inserts the tiny
    softmax-combine collectives ([B,H] max/sum), instead of gathering or
    replicating the cache.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # The new token's K/V come out of the TP projection sharded on hd; the
    # cache is seq-sharded. Replicate the (tiny) new KV before the write so
    # GSPMD never reshards the cache to reconcile the two layouts.
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    k_cache = cache_write(k_cache, k, pos)
    v_cache = cache_write(v_cache, v, pos)
    k_cache = constrain(k_cache, "dp", "tp", None, None)
    v_cache = constrain(v_cache, "dp", "tp", None, None)
    smax = k_cache.shape[1]
    kheads = k_cache.shape[2]
    rep = q.shape[2] // kheads
    kk = k_cache.astype(q.dtype)
    vv = v_cache.astype(q.dtype)
    # Grouped-query einsum directly against the cache — repeating KV here
    # would materialize rep x the cache per layer.
    qg = q.reshape(b, 1, kheads, rep, q.shape[-1])
    with jax.named_scope("attn_core"):
        scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, kk).astype(jnp.float32)
        scores = constrain(scores, "dp", None, None, None, "tp")
        scores = scores / (q.shape[-1] ** 0.5)
        valid = (jnp.arange(smax, dtype=jnp.int32) <= pos)[None, None, None, None, :]
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkrqs,bskv->bqkrv", w, vv)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, k_cache, v_cache


# ------------------------------------------------------------------ MLA attn


def mla_schema(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wdq": ParamDef((d, qr), "normal", ("fsdp", None)),
        "q_norm": norm_schema(qr),
        "wuq": ParamDef((qr, h * (nope + rope_d)), "normal", (None, "tp")),
        "wdkv": ParamDef((d, kvr + rope_d), "normal", ("fsdp", None)),
        "kv_norm": norm_schema(kvr),
        "wuk": ParamDef((kvr, h * nope), "normal", (None, "tp")),
        "wuv": ParamDef((kvr, h * vd), "normal", (None, "tp")),
        "wo": ParamDef((h * vd, d), "scaled", ("tp", "fsdp")),
    }


def _mla_qkv(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """Returns q (nope+rope per head), latent ckv, shared roped k_rope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ p["wdkv"]
    ckv = rmsnorm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(dkv[..., cfg.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_rope[:, :, 0, :]


def mla_forward(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """Direct-form MLA for train/prefill. Returns (out, (ckv, k_rope))."""
    b, s, _ = x.shape
    h, nope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, positions, cfg)
    k_nope = (ckv @ p["wuk"]).reshape(b, s, h, nope)
    v = (ckv @ p["wuv"]).reshape(b, s, h, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], cfg.qk_rope_dim))],
        axis=-1,
    )
    out = attention_op(
        q, k, v, positions, positions, cfg.causal,
        chunk_threshold=cfg.long_context_threshold, chunk=cfg.attn_chunk,
        impl=cfg.attention_impl,
    )
    out = out.reshape(b, s, -1) @ p["wo"]
    return constrain(out, "dp", "sp", None), (ckv, k_rope)


def mla_decode(
    p: dict,
    x: jax.Array,  # [B, 1, D]
    pos: jax.Array,
    ckv_cache: jax.Array,  # [B, Smax, kv_rank]
    krope_cache: jax.Array,  # [B, Smax, rope_d]
    cfg: ModelConfig,
):
    """Absorbed-form MLA decode: scores in latent space, no decompression.

    score = q_nope @ W_uk^T  ·  ckv_cached  +  q_rope · k_rope_cached
    out   = (softmax @ ckv_cached) @ W_uv, per head.
    """
    b = x.shape[0]
    h, nope, vd, kvr = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, positions, cfg)
    ckv_cache = cache_write(ckv_cache, ckv, pos)
    krope_cache = cache_write(krope_cache, k_rope, pos)
    ckv_cache = constrain(ckv_cache, "dp", "tp", None)
    krope_cache = constrain(krope_cache, "dp", "tp", None)
    wuk = p["wuk"].reshape(kvr, h, nope)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, wuk)  # absorb W_uk into q
    scores = (
        jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv_cache.astype(q_lat.dtype))
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, krope_cache.astype(q_rope.dtype))
    ).astype(jnp.float32)
    scale = 1.0 / ((nope + cfg.qk_rope_dim) ** 0.5)
    smax = ckv_cache.shape[1]
    valid = jnp.arange(smax)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores * scale, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    lat_out = jnp.einsum("bhqs,bsk->bqhk", w, ckv_cache.astype(x.dtype))
    wuv = p["wuv"].reshape(kvr, h, vd)
    out = jnp.einsum("bqhk,khv->bqhv", lat_out, wuv)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, ckv_cache, krope_cache


# -------------------------------------------------------------------- SwiGLU


def mlp_schema(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    return {
        "wi_gate": ParamDef((d, f), "normal", ("fsdp", "tp")),
        "wi_up": ParamDef((d, f), "normal", ("fsdp", "tp")),
        "wo": ParamDef((f, d), "scaled", ("tp", "fsdp")),
    }


def mlp_forward(p: dict, x: jax.Array) -> jax.Array:
    x = constrain(x, "dp", None, None)  # SP gather at MLP entry
    gate = constrain(x @ p["wi_gate"], "dp", None, "tp")
    up = constrain(x @ p["wi_up"], "dp", None, "tp")
    return constrain((jax.nn.silu(gate) * up) @ p["wo"], "dp", "sp", None)
