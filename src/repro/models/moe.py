"""Top-k routed mixture-of-experts with capacity-based einsum dispatch.

GShard/MaxText-style formulation: tokens are grouped, each group dispatches
into per-expert capacity buffers with one-hot einsums. This keeps the whole
layer expressible as dense einsums (pjit/GSPMD shard it with all-to-alls when
experts live on the 'model' axis) at ~k/E of dense-all-experts FLOPs plus a
small dispatch overhead. Tokens overflowing an expert's capacity are dropped
(standard GShard semantics); capacity_factor controls the drop rate.

Aux losses: Switch-style load-balance loss + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

__all__ = ["moe_schema", "moe_forward"]


def moe_schema(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), "normal", ("fsdp", None)),
        # Experts sharded over 'model' (EP); D over 'data' (ZeRO-3).
        "w_gate": ParamDef((e, d, f), "normal", ("tp", "fsdp", None)),
        "w_up": ParamDef((e, d, f), "normal", ("tp", "fsdp", None)),
        "w_down": ParamDef((e, f, d), "scaled", ("tp", None, "fsdp")),
    }


def moe_forward(
    p: dict,
    x: jax.Array,  # [B, S, D]
    cfg: ModelConfig,
    group_size: int = 1024,
):
    """Returns (y [B, S, D], aux_metrics dict incl. load-balance loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = b * s
    g = min(group_size, tokens)
    assert tokens % g == 0, (tokens, g)
    ng = tokens // g
    xt = constrain(x.reshape(ng, g, d), "dp", None, None)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # [ng, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [ng, g, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(cfg.moe_capacity_factor * g * k / e))
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # [ng, g, k, E]
    # Position of each (token, choice) within its expert's buffer.
    flat = onehot.reshape(ng, g * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(ng, g, k, e)
    pos = (pos * onehot).sum(-1)  # [ng, g, k]
    within = pos < capacity
    expert_of = onehot * within[..., None]  # mask dropped tokens
    pos_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # [ng, g, k, C]
    # dispatch[ng, g, E, C] — at most one (E, C) slot per (token, choice).
    dispatch = jnp.einsum("gtke,gtkc->gtec", expert_of, pos_onehot)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", expert_of, pos_onehot,
                         gate_vals.astype(jnp.float32))

    xd = constrain(dispatch.astype(xt.dtype), "dp", None, "tp", None)
    combine = constrain(combine, "dp", None, "tp", None)
    # EP: expert dim over 'model' (the dispatch einsum becomes the all-to-all),
    # token-group dim stays on the batch axes. Expert weights are ZeRO-stored
    # (D over 'data'); gather them HERE (FSDP unroll, ~130 MB/expert) so the
    # weight-grad einsums never gather the 16 GB activation cotangents.
    wg = constrain(p["w_gate"], "tp", None, None)
    wu = constrain(p["w_up"], "tp", None, None)
    wd = constrain(p["w_down"], "tp", None, None)
    x_e = constrain(jnp.einsum("gtec,gtd->gecd", xd, xt), "dp", "tp", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_e, wg)) * jnp.einsum(
        "gecd,edf->gecf", x_e, wu
    )
    h = constrain(h, "dp", "tp", None, None)
    y_e = constrain(
        jnp.einsum("gecf,efd->gecd", h, wd), "dp", "tp", None, None
    )
    y = constrain(
        jnp.einsum("gtec,gecd->gtd", combine.astype(xt.dtype), y_e),
        "dp", None, None,
    )
    y = constrain(y.reshape(b, s, d), "dp", "sp", None)

    # Switch load-balance loss: E * sum_e f_e * p_e  (f = token fraction,
    # p = mean router prob); plus z-loss for logit stability.
    f_e = onehot.sum(axis=(1, 2)) / g  # [ng, E] fraction routed (pre-drop)
    p_e = probs.mean(axis=1)  # [ng, E]
    balance = e * (f_e * p_e).sum(-1).mean()
    zloss = (jax.nn.logsumexp(logits, axis=-1) ** 2).mean()
    aux = {
        "moe_balance_loss": balance,
        "moe_z_loss": zloss,
        "moe_dropped_frac": 1.0 - within.mean() if k else 0.0,
    }
    return y, aux
